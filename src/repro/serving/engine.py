"""Generation engine: compiled prefill + batched decode with slot management.

The engine owns a fixed-capacity decode batch (``max_batch`` slots, each
with a ``max_seq`` cache). Requests are prefetched one at a time (prompt
padded to a power-of-two bucket so the number of compiled prefill programs
stays small) and *inserted* into a free slot of the running batch cache —
the mechanism continuous batching (scheduler.py) is built on.

All hot functions are jitted once per (bucket) shape:
- ``_prefill_one``: prompt [1, bucket] -> (last logits, single-slot cache)
- ``_insert``: copy a single-slot cache into slot ``i`` of the batch cache
- ``_decode``: one step for all slots (+ sampling), inactive slots masked
- ``_chunk``: ``lax.scan`` over ``decode_chunk`` fused decode steps with
  on-device sampling and per-slot termination masks (EOS / token budget /
  ``max_seq`` capacity) — the scheduler syncs to host once per chunk
  instead of once per token.

The decode fast path is *sync-free*: the engine keeps the next input token
per slot on device (``_next_tok``). ``insert_request`` computes the first
generated token with an on-device argmax and returns it as an unforced
device scalar, so admitting a request never blocks the host on a
device->host read — the prefill dispatch overlaps the in-flight decode
chunk and the scheduler reads tokens at its single per-chunk sync point.

KV memory is either *contiguous* (each slot owns a ``max_seq`` cache —
memory scales with capacity) or *paged* (``paged=True``: a shared pool of
``kv_pool_blocks`` pages of ``page_size`` tokens, addressed through
per-slot block tables — memory scales with actual context). The engine
owns the page allocator host-side (free list + table mirror; device rows
are pushed asynchronously, never a sync): prefill allocates the prompt's
pages plus the first decode write's page, ``ensure_capacity`` secures one
page per upcoming KV write, and retire/cancel returns every page. Paging
applies to linear attention caches only; ring families (ssm / hybrid /
sliding-window) silently keep the linear layout.

Prompt accounting is two-track: ``_lengths`` / ``context_len`` are the
PHYSICAL cache lengths (ring families pad prompts to their bucket and
treat pads as context), while ``logical_len`` / ``kv_stats`` report what
the client actually sent — padding is never billed as usage.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.sampling import mask_padded_vocab

F32 = jnp.float32


def _bucket(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


@dataclass
class GenerationResult:
    tokens: List[int]
    prompt_len: int
    steps: int
    finished: bool
    latency_s: float = 0.0
    # time-to-first-token, measured at the first host sync that revealed a
    # token (None on paths that don't time it, e.g. format_generation's
    # synthetic results) — lets the sync service report real TTFT
    first_token_s: Optional[float] = None


class GenerationEngine:
    """Single-host serving engine for one model asset."""

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_seq: int = 512, eos_id: Optional[int] = None,
                 decode_chunk: int = 8, paged: bool = False,
                 page_size: int = 16, kv_pool_blocks: Optional[int] = None,
                 extra_inputs: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        # fused decode steps per host sync (compile-stable; per-slot budgets
        # stop individual sequences mid-chunk). Floored to a power of two
        # up front: the scheduler's budget alignment only ever uses pow2
        # lengths, so accepting e.g. 12 verbatim would silently run 8
        self.decode_chunk = 1 << (max(1, int(decode_chunk)).bit_length() - 1)
        # static per-request extra inputs (e.g. image embeds builder)
        self.extra_inputs = extra_inputs or {}

        # Ring-cache families (sliding-window / hybrid local attention / SSM
        # state) left-pad prompts and wrap or accumulate their caches —
        # they keep the linear layout. A sliding window >= max_seq never
        # wraps, so such engines are plain linear caches (no bucket
        # padding charged, pageable). Paged KV applies to linear attention
        # caches only; asking for it elsewhere falls back silently (linear
        # stays the default for ring families).
        self._ring = (self.cfg.family in ("hybrid", "ssm")
                      or (self.cfg.sliding_window is not None
                          and self.cfg.sliding_window < max_seq))
        pageable = not self._ring and self.cfg.family != "audio"
        self.paged = bool(paged) and pageable
        if self.paged:
            if max_seq % page_size:
                raise ValueError(
                    f"page_size {page_size} must divide max_seq {max_seq}")
            self.page_size = page_size
            self._pages_per_slot = max_seq // page_size
            # default pool = same capacity as the contiguous layout; the
            # win is that admission and occupancy are charged per page in
            # use, and a smaller pool (oversubscription) is a valid config
            self.kv_pool_blocks = int(kv_pool_blocks) if kv_pool_blocks \
                else max_batch * self._pages_per_slot
            self._free_pool: List[int] = list(range(self.kv_pool_blocks))
            self._slot_blocks: List[List[int]] = [[] for _ in range(max_batch)]
            # host mirror of the device block table (sentinel = pool size)
            self._table = np.full((max_batch, self._pages_per_slot),
                                  self.kv_pool_blocks, np.int32)
            self._cache = model.init_cache(
                max_batch, max_seq, paged=(self.kv_pool_blocks, page_size))
            self._insert = jax.jit(self._insert_paged_impl,
                                   donate_argnums=(0,))
        else:
            self.page_size = 0
            self.kv_pool_blocks = 0
            self._free_pool = []
            self._slot_blocks = [[] for _ in range(max_batch)]
            self._cache = model.init_cache(max_batch, max_seq)
            self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self._lengths = np.zeros((max_batch,), np.int32)
        self._active = np.zeros((max_batch,), bool)
        # logical vs physical prompt accounting: ring families pad prompts
        # to their bucket and treat pads as context, so _lengths (physical,
        # cache bookkeeping) may exceed the user's prompt. Usage and stats
        # report the logical numbers.
        self._prompt_lens = np.zeros((max_batch,), np.int32)   # logical
        self._prefill_lens = np.zeros((max_batch,), np.int32)  # physical
        # device-resident next input token per slot (sync-free admission:
        # insert_request writes it with an on-device argmax, step_chunk
        # carries it forward — the host never has to know it)
        self._next_tok = jnp.zeros((max_batch,), jnp.int32)

        self._kv_bytes_per_token = self._bytes_per_token(self._cache)
        self._prefill_jit: Dict[int, Any] = {}
        self._decode = jax.jit(self._decode_impl)
        # one compiled scan per chunk length actually used (lazy, bounded
        # by decode_chunk): the scheduler aligns chunks to the earliest
        # completion, so short lengths recur and long ones amortize
        self._chunk_jit: Dict[int, Any] = {}
        self._first_tok = jax.jit(self._first_tok_impl)

    @staticmethod
    def _bytes_per_token(cache) -> int:
        """Device bytes one token of context costs across all layers (the
        unit for KV-memory accounting; 0 for constant-state SSM caches)."""
        if "k_pool" in cache:
            kp = cache["k_pool"]                   # [L, N, P, KV, hd]
            per_entry = int(np.prod(kp.shape[3:])) * kp.dtype.itemsize
            return 2 * kp.shape[0] * per_entry
        for key in ("k", "attn_k"):                # [L|nb, B, S, KV, hd]
            if key in cache:
                k = cache[key]
                per_entry = int(np.prod(k.shape[3:])) * k.dtype.itemsize
                return 2 * k.shape[0] * per_entry
        return 0

    # -- jitted internals ---------------------------------------------------

    def _prefill_impl(self, params, batch):
        return self.model.prefill(params, batch, cache_len=self.max_seq)

    def _insert_impl(self, batch_cache, one_cache, slot):
        """Copy a B=1 cache into slot ``slot`` of the batch cache.

        The batch axis of each leaf is located structurally: the first axis
        where the source is 1 and the destination is ``max_batch``. (Leading
        layer-stack dims match between src and dst, so they never trigger.)
        """
        def put(dst, src):
            if dst.ndim == 1:                       # lengths [B]
                return dst.at[slot].set(src[0])
            for ax in range(dst.ndim):
                if src.shape[ax] == 1 and dst.shape[ax] == self.max_batch:
                    idx = (slice(None),) * ax + (slot,)
                    return dst.at[idx].set(jnp.squeeze(src, ax))
            return dst
        return jax.tree.map(put, batch_cache, one_cache)

    def _insert_paged_impl(self, batch_cache, one_cache, table_row, slot):
        """Scatter a B=1 linear prefill cache into the slot's pool pages.

        ``table_row`` [pages_per_slot] holds the slot's pool page ids
        (sentinel ``kv_pool_blocks`` for pages past the prompt — their
        scatters drop). The prefill cache is always ``max_seq`` long, so it
        reshapes exactly into pages_per_slot pages.
        """
        nb, P = self._pages_per_slot, self.page_size

        def put_pool(pool, src):
            pages = jnp.squeeze(src, 1).reshape(
                src.shape[0], nb, P, *src.shape[3:])
            return pool.at[:, table_row].set(pages.astype(pool.dtype),
                                             mode="drop")

        cache = dict(batch_cache)
        cache["k_pool"] = put_pool(batch_cache["k_pool"], one_cache["k"])
        cache["v_pool"] = put_pool(batch_cache["v_pool"], one_cache["v"])
        cache["lengths"] = batch_cache["lengths"].at[slot].set(
            one_cache["lengths"][0])
        cache["block_table"] = batch_cache["block_table"].at[slot].set(
            table_row)
        return cache

    # -- paged pool management (host side; device work stays sync-free) -----

    def _alloc_blocks(self, slot: int, n: int) -> bool:
        """Move ``n`` pool pages to ``slot`` (all-or-nothing)."""
        if len(self._free_pool) < n:
            return False
        start = len(self._slot_blocks[slot])
        for i in range(n):
            blk = self._free_pool.pop()
            self._slot_blocks[slot].append(blk)
            self._table[slot, start + i] = blk
        return True

    def _push_table_row(self, slot: int):
        """Mirror the slot's host table row to the device cache (a tiny
        async host->device transfer — never a sync)."""
        self._cache["block_table"] = self._cache["block_table"].at[slot].set(
            jnp.asarray(self._table[slot]))

    def free_blocks(self) -> int:
        """Unallocated pool pages (0 for contiguous engines)."""
        return len(self._free_pool)

    def blocks_in_use(self) -> int:
        return self.kv_pool_blocks - len(self._free_pool)

    def blocks_for_prompt(self, n: int) -> int:
        """Pool pages admission must see free before taking an ``n``-token
        prompt: its prefill pages plus room for the first decode write."""
        true_len = _bucket(n) if self._ring else n
        return -(-(true_len + 1) // self.page_size)

    def can_admit(self, n: int) -> bool:
        """Block-aware admission gate: beyond :meth:`fits_prompt`, a paged
        engine also needs enough free pool pages for the prompt."""
        if not self.fits_prompt(n):
            return False
        if not self.paged:
            return True
        return len(self._free_pool) >= self.blocks_for_prompt(n)

    def ensure_capacity(self, slot: int, want: int) -> int:
        """Secure write headroom for up to ``want`` more KV entries on
        ``slot``, allocating pool pages as needed and available. Returns
        the writes actually available — may be < ``want`` when the pool is
        tight, 0 when the slot cannot take a single further write (the
        caller retires it). Contiguous engines just report the remaining
        ``max_seq`` headroom. Idempotent and allocation-only (pages free on
        retire, never mid-flight)."""
        length = int(self._lengths[slot])
        phys = self.max_seq - length
        if not self.paged:
            return max(0, min(want, phys))
        want = min(want, phys)
        have = len(self._slot_blocks[slot]) * self.page_size - length
        dirty = False
        while have < want and self._free_pool \
                and len(self._slot_blocks[slot]) < self._pages_per_slot:
            self._alloc_blocks(slot, 1)
            have += self.page_size
            dirty = True
        if dirty:
            self._push_table_row(slot)
        return max(0, min(want, have))

    def _first_tok_impl(self, logits, next_tok, slot):
        """First generated token from prefill logits (greedy over the
        logical vocab), written into the device next-token buffer."""
        masked = mask_padded_vocab(logits[0], self.cfg.vocab_size)
        first = jnp.argmax(masked).astype(jnp.int32)
        return first, next_tok.at[slot].set(first)

    def _sample(self, logits, rng, temperature):
        """Per-slot-temperature sampling: rows at 0 take the greedy argmax."""
        masked = mask_padded_vocab(logits, self.cfg.vocab_size)
        greedy = jnp.argmax(masked, axis=-1).astype(jnp.int32)
        scaled = masked / jnp.maximum(temperature, 1e-6)[:, None]
        sampled = jax.random.categorical(rng, scaled, axis=-1) \
            .astype(jnp.int32)
        return jnp.where(temperature > 0, sampled, greedy)

    def _decode_impl(self, params, cache, tokens, rng, temperature, active):
        """One decode step; ``temperature`` is a per-slot [max_batch]
        vector so mixed-temperature batches don't interfere — each row
        samples at its own temperature. The fixed vector shape keeps the
        step compile-stable. ``active`` gates both sampling output and the
        per-slot cache-length advance (a slot at ``max_seq`` capacity must
        not write past its cache)."""
        logits, cache = self.model.decode_step(params, cache, tokens,
                                               active=active)
        nxt = self._sample(logits, rng, temperature)
        nxt = jnp.where(active, nxt, 0)
        return nxt, cache

    def _runnable(self, tok, left, lengths, run):
        """Per-slot continuation mask: a slot keeps decoding while it has
        token budget, cache capacity for the next KV write, and its input
        token is not EOS."""
        run = run & (left > 0) & (lengths < self.max_seq)
        if self.eos_id is not None:
            run = run & (tok != self.eos_id)
        return run

    def _unpage(self, cache):
        """Gather the block-table view into a contiguous linear cache
        (``[L, B, S, KV, hd]``). Sentinel table entries clamp to an
        arbitrary page whose data sits past the owner's length — masked."""
        bt = jnp.clip(cache["block_table"], 0, self.kv_pool_blocks - 1)

        def gather(pool):
            g = pool[:, bt]                       # [L, B, nb, P, KV, hd]
            return g.reshape(g.shape[0], g.shape[1], -1, *g.shape[4:])

        return {"lengths": cache["lengths"],
                "k": gather(cache["k_pool"]), "v": gather(cache["v_pool"])}

    def _repage(self, cache, work):
        """Scatter a chunk's updated contiguous view back into the pool.
        Unallocated (sentinel) pages scatter out of bounds and drop, so
        writes past a slot's allocation never touch foreign pages."""
        table = cache["block_table"]
        nb = table.shape[1]

        def scatter(pool, kc):
            pages = kc.reshape(kc.shape[0], kc.shape[1], nb, self.page_size,
                               *kc.shape[3:])
            return pool.at[:, table].set(pages.astype(pool.dtype),
                                         mode="drop")

        return dict(cache,
                    k_pool=scatter(cache["k_pool"], work["k"]),
                    v_pool=scatter(cache["v_pool"], work["v"]),
                    lengths=work["lengths"])

    def _chunk_impl(self, k, params, cache, next_tok, rng, temperature,
                    budgets, active):
        """Fused multi-step decode: ``lax.scan`` over ``k`` steps with
        on-device sampling and termination.

        Per step, slots whose mask is off keep their input token and do not
        advance their cache length; the step's KV/state writes for them land
        past their valid length (invisible) and are overwritten on the next
        insert. Returns (cache, next_tok, tokens [B, K], emitted [B, K])
        where ``emitted[b]`` is a contiguous prefix mask — once a slot
        terminates it never resumes within the chunk.

        Paged caches on the ORACLE backend are translated at the CHUNK
        boundary: the block table is fixed across a chunk (the scheduler
        secures every page before dispatch), so the pages gather into a
        contiguous working view once, the whole chunk runs on the linear
        fast path, and the touched pages scatter back once —
        layout-translation cost amortizes over the chunk exactly like the
        host sync does. On the Pallas backends no translation happens at
        all: each step runs the block-table decode kernel against the pool
        in place. (The backend is baked in at trace time like every other
        kernel dispatch; engines are built per backend.)

        RNG parity contract (property-tested): step ``i`` uses ``sub_i``
        from the chain ``rng_i, sub_i = split(rng_{i-1})`` — identical to
        driving ``decode_chunk`` single ``step()`` calls with the same
        chain, so fused and stepwise decode are token-identical.
        """
        from repro.kernels import ops as _kops
        translate = "k_pool" in cache and _kops.get_backend() == "ref"
        work = self._unpage(cache) if translate else cache

        def body(carry, _):
            work, tok, rng, run, left = carry
            rng, sub = jax.random.split(rng)
            logits, work = self.model.decode_step(params, work, tok,
                                                  active=run)
            nxt = self._sample(logits, sub, temperature)
            # dead slots hold their token: keeps the carry stable and the
            # (batch-coupled, e.g. MoE-capacity) compute deterministic
            nxt = jnp.where(run, nxt, tok)
            left = left - run.astype(jnp.int32)
            run_next = self._runnable(nxt, left, work["lengths"], run)
            return (work, nxt, rng, run_next, left), (nxt, run)

        run0 = self._runnable(next_tok, budgets, work["lengths"], active)
        (work, tok, _, _, _), (toks, emitted) = jax.lax.scan(
            body, (work, next_tok, rng, run0, budgets), None, length=k)
        cache = self._repage(cache, work) if translate else work
        return (cache, tok,
                jnp.swapaxes(toks, 0, 1), jnp.swapaxes(emitted, 0, 1))

    # -- public API ------------------------------------------------------------

    def fits_prompt(self, n: int) -> bool:
        """Whether an ``n``-token prompt is admissible: its padding bucket
        must not exceed ``max_seq`` AND its *physical* prefill length
        (the bucket itself for ring families, which treat pads as context)
        must leave at least one KV write of generation headroom. A prompt
        that fills the cache would burn a prefill + slot only to retire
        with nothing generated beyond the prefill token — callers reject
        it at validation time (``PROMPT_TOO_LONG``) instead."""
        bucket = _bucket(n)
        if bucket > self.max_seq:
            return False
        true_len = bucket if self._ring else n
        return true_len < self.max_seq

    def max_prompt_len(self) -> int:
        """Longest admissible prompt in tokens — consistent with
        :meth:`fits_prompt` by construction, so a caller that truncates to
        this length is never rejected. Ring families are bounded by the
        padding bucket (largest bucket strictly below ``max_seq``); linear
        engines by ``max_seq - 1`` — unless ``max_seq`` is not a bucket
        size itself, where the bound drops to the largest bucket that
        still fits (e.g. max_seq=100 admits at most 64: a 99-token prompt
        would pad to a 128 bucket)."""
        if not self._ring:
            n = self.max_seq - 1
            if n > 0 and _bucket(n) <= self.max_seq:
                return n
        b = 16                       # _bucket's minimum
        if b > self.max_seq or (self._ring and b >= self.max_seq):
            return 0
        limit = self.max_seq - 1 if self._ring else self.max_seq
        while b * 2 <= limit:
            b *= 2
        return b

    def free_slots(self) -> List[int]:
        return [i for i in range(self.max_batch) if not self._active[i]]

    def context_len(self, slot: int) -> int:
        """Physical cache length of ``slot`` (cache bookkeeping: includes
        ring-family padding)."""
        return int(self._lengths[slot])

    def logical_len(self, slot: int) -> int:
        """User-visible context of ``slot``: prompt tokens as submitted
        plus generated tokens — ring-family padding is not billed."""
        return int(self._prompt_lens[slot]
                   + (self._lengths[slot] - self._prefill_lens[slot]))

    def active_logical_tokens(self) -> int:
        gen = self._lengths - self._prefill_lens
        return int(((self._prompt_lens + gen) * self._active).sum())

    def capacity_left(self, slot: int) -> int:
        """KV writes remaining before ``slot`` cannot decode another token.
        Pool-aware on paged engines: bounded by ``max_seq`` AND by the
        slot's allocated pages plus what the shared pool could still
        provide."""
        left = int(self.max_seq - self._lengths[slot])
        if self.paged:
            have = (len(self._slot_blocks[slot]) * self.page_size
                    - int(self._lengths[slot]))
            left = min(left, have + len(self._free_pool) * self.page_size)
        return max(0, left)

    def kv_stats(self) -> Dict[str, Any]:
        """KV memory accounting. A contiguous cache charges the full
        ``max_seq`` per occupied slot (memory scales with *capacity*); a
        paged cache charges the pool pages actually allocated (memory
        scales with *actual context*). ``active_tokens`` is the logical
        context — ring-family padding is not billed as context."""
        bpt = self._kv_bytes_per_token
        active = int(self._active.sum())
        logical = self.active_logical_tokens()
        if self.paged:
            used = self.blocks_in_use()
            in_use = used * self.page_size * bpt
            out: Dict[str, Any] = {
                "paged": True, "page_size": self.page_size,
                "pool_blocks": self.kv_pool_blocks,
                "blocks_in_use": used,
                "free_blocks": len(self._free_pool),
            }
        else:
            in_use = active * self.max_seq * bpt
            out = {"paged": False}
        out.update(
            active_slots=active,
            active_tokens=logical,
            kv_bytes_per_token=bpt,
            kv_bytes_in_use=int(in_use),
            kv_bytes_per_active_token=(round(in_use / logical, 1)
                                       if logical else 0.0),
        )
        return out

    def insert_request(self, prompt: List[int], slot: int,
                       extra: Optional[Dict[str, Any]] = None) -> jax.Array:
        """Prefill ``prompt`` into ``slot``; returns the first generated
        token as an *unforced* device scalar (greedy argmax over the prefill
        logits, computed on device). Callers defer the host read to their
        next sync point — admission never stalls the decode loop."""
        assert not self._active[slot], f"slot {slot} busy"
        bucket = _bucket(len(prompt))
        if bucket > self.max_seq:
            raise ValueError(f"prompt {len(prompt)} exceeds max_seq {self.max_seq}")
        if bucket not in self._prefill_jit:
            self._prefill_jit[bucket] = jax.jit(self._prefill_impl)
        # Ring-cache families (sliding-window / hybrid local attention) need
        # contiguous positions, so their prompts are LEFT-padded and pads are
        # treated as context. Linear caches RIGHT-pad; causal masking keeps
        # pads out of real-token attention and decode masks by true length.
        # (SSM states are cumulative too, so stateful families all left-pad.)
        ring = self._ring
        padded = np.zeros((1, bucket), np.int32)
        if ring:
            padded[0, bucket - len(prompt):] = prompt
            true_len = bucket
        else:
            padded[0, :len(prompt)] = prompt
            true_len = len(prompt)
        batch = {"tokens": jnp.asarray(padded),
                 "prompt_lengths": jnp.asarray([true_len], np.int32)}
        for k, v in (extra or self.extra_inputs).items():
            batch[k] = v
        if self.paged:
            # allocate the prefill's pages — plus the page the FIRST decode
            # write lands in, so a fresh admission can never be starved by
            # co-tenants before its first chunk — BEFORE dispatching
            # compute; the scheduler gates admission on can_admit so this
            # only trips for direct callers outrunning the pool.
            # blocks_for_prompt is the ONE statement of this reservation
            # rule: the admission gate and the allocator must never diverge
            need = self.blocks_for_prompt(len(prompt))
            if not self._alloc_blocks(slot, need):
                raise RuntimeError(
                    f"KV pool exhausted: prompt needs {need} pages, "
                    f"{len(self._free_pool)} of {self.kv_pool_blocks} free")
        # host mirrors flip BEFORE the (possibly compiling) prefill
        # dispatch: stats readers on other threads must never observe
        # allocated pages without an owner
        self._lengths[slot] = true_len
        self._prompt_lens[slot] = len(prompt)
        self._prefill_lens[slot] = true_len
        self._active[slot] = True
        try:
            logits, one_cache = self._prefill_jit[bucket](self.params, batch)
            if self.paged:
                self._cache = self._insert(
                    self._cache, one_cache, jnp.asarray(self._table[slot]),
                    jnp.asarray(slot, jnp.int32))
            else:
                self._cache = self._insert(self._cache, one_cache,
                                           jnp.asarray(slot, jnp.int32))
            first, self._next_tok = self._first_tok(
                logits, self._next_tok, jnp.asarray(slot, jnp.int32))
        except Exception:
            self.release_slot(slot)   # no orphaned slot or leaked pages
            raise
        return first

    def release_slot(self, slot: int):
        self._active[slot] = False
        if self.paged and self._slot_blocks[slot]:
            # free-on-retire: every page returns to the shared pool. The
            # sentinel row must reach the DEVICE table too: an inactive
            # slot still executes (masked) decode writes, and a stale row
            # would alias pages that now belong to another slot.
            self._free_pool.extend(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self._table[slot, :] = self.kv_pool_blocks
            self._push_table_row(slot)

    def step(self, tokens: np.ndarray, rng, temperature=0.0):
        """One decode step for the whole batch. tokens [max_batch] int32;
        ``temperature`` is a scalar (applied to every slot) or a per-slot
        [max_batch] vector. Slots whose cache is full (length == max_seq,
        or — paged — no page obtainable for the next write) are masked:
        they emit 0 and do not advance — lengths never grow past the
        writable cache."""
        writable = self._active & (self._lengths < self.max_seq)
        if self.paged:
            for i in np.flatnonzero(writable):
                if self.ensure_capacity(int(i), 1) < 1:
                    writable[i] = False
        active = jnp.asarray(writable)
        temps = np.broadcast_to(np.asarray(temperature, np.float32),
                                (self.max_batch,))
        nxt, self._cache = self._decode(
            self.params, self._cache, jnp.asarray(tokens, jnp.int32), rng,
            jnp.asarray(temps, F32), active)
        self._lengths[writable] += 1
        return np.asarray(nxt)

    def step_chunk(self, rng, temperature, budgets, k: Optional[int] = None
                   ) -> Tuple[jax.Array, jax.Array]:
        """Dispatch one fused chunk of ``k`` (default ``decode_chunk``)
        decode steps.

        ``budgets`` [max_batch] int32 = tokens each slot may still emit
        (0 for free slots). Input tokens come from the device-resident
        ``_next_tok`` buffer (written by ``insert_request`` and the
        previous chunk), so no host state crosses to the device. Callers
        (the scheduler) pass ``k = min(decode_chunk, earliest remaining
        budget)`` so a chunk never runs masked steps past the first
        completion — short requests sync at per-token cadence, long
        co-batches amortize the full chunk.

        Returns unforced device arrays ``(tokens [B, k], emitted [B, k])``;
        the caller reads both in ONE host sync and then calls
        :meth:`commit_chunk` with the per-slot emission counts.
        """
        # k is the caller's explicit choice (the scheduler budget-aligns
        # it); decode_chunk is only the default
        k = self.decode_chunk if k is None else max(1, int(k))
        if k not in self._chunk_jit:
            self._chunk_jit[k] = jax.jit(partial(self._chunk_impl, k))
        if self.paged:
            # every budgeted write this chunk needs an allocated page
            # BEFORE dispatch (the device cannot allocate); clamping the
            # budget to the secured headroom freezes a starved slot at a
            # page boundary exactly like a max_seq-full one. The scheduler
            # pre-ensures and retires starved requests — this second call
            # is an idempotent no-op there and a guard for direct callers.
            budgets = np.asarray(budgets, np.int32).copy()
            for i in np.flatnonzero(self._active & (budgets > 0)):
                budgets[i] = self.ensure_capacity(int(i),
                                                  min(k, int(budgets[i])))
        temps = np.broadcast_to(np.asarray(temperature, np.float32),
                                (self.max_batch,))
        self._cache, self._next_tok, toks, emitted = self._chunk_jit[k](
            self.params, self._cache, self._next_tok, rng,
            jnp.asarray(temps, F32), jnp.asarray(budgets, jnp.int32),
            jnp.asarray(self._active))
        return toks, emitted

    def commit_chunk(self, emitted_counts: np.ndarray):
        """Fold a chunk's per-slot emission counts into the host-side
        length mirror (each emitted token wrote exactly one KV/state entry)."""
        self._lengths += np.asarray(emitted_counts, np.int32)

    # -- convenience: synchronous batch generation ------------------------------

    def generate(self, prompts: List[List[int]], *, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 extras: Optional[List[Dict[str, Any]]] = None,
                 ) -> List[GenerationResult]:
        """Generate for up to ``max_batch`` prompts at once (convenience path;
        the scheduler drives the slot API directly for continuous batching)."""
        assert len(prompts) <= self.max_batch
        t0 = time.perf_counter()
        rng = jax.random.PRNGKey(seed)
        last_tok = np.zeros((self.max_batch,), np.int32)
        outs: List[List[int]] = [[] for _ in prompts]
        try:
            firsts = [self.insert_request(p, i,
                                          extra=extras[i] if extras else None)
                      for i, p in enumerate(prompts)]
        except Exception:
            # a failed insert (e.g. pool exhausted mid-batch) must not
            # strand the prompts already inserted: their slots would stay
            # active with their pages allocated forever
            for i in range(len(prompts)):
                self.release_slot(i)
            raise
        for i, f in enumerate(firsts):            # one deferred sync point
            first = int(f)
            outs[i].append(first)
            last_tok[i] = first
        t_first = time.perf_counter() - t0        # all prefills + first toks
        done = [False] * len(prompts)
        capped = [False] * len(prompts)
        for step in range(max_new_tokens - 1):
            # a slot at cache capacity cannot decode another token — stop
            # rather than collect the masked 0s step() emits for it (the
            # scheduler path retires the same condition as MAX_SEQ_EXCEEDED;
            # here the result reports finished=False)
            for i in range(len(prompts)):
                if not done[i] and self.capacity_left(i) <= 0:
                    done[i] = capped[i] = True
                    self.release_slot(i)
            if all(done):
                break
            rng, sub = jax.random.split(rng)
            nxt = self.step(last_tok, sub, temperature)
            for i in range(len(prompts)):
                if done[i]:
                    continue
                tok = int(nxt[i])
                outs[i].append(tok)
                last_tok[i] = tok
                if self.eos_id is not None and tok == self.eos_id:
                    # release NOW, not at the end of the batch: a done slot
                    # left active keeps decoding (wasted compute) and keeps
                    # advancing its cache length — drifting vs the
                    # scheduler path's chunk-boundary retire
                    done[i] = True
                    self.release_slot(i)
            if all(done):
                break
        dt = time.perf_counter() - t0
        results = []
        for i, p in enumerate(prompts):
            finished = bool(done[i]) if self.eos_id is not None else True
            results.append(GenerationResult(
                tokens=outs[i], prompt_len=len(p), steps=len(outs[i]),
                finished=finished and not capped[i],   # capacity-truncated
                latency_s=dt, first_token_s=t_first))
            self.release_slot(i)
        return results
