"""Generation engine: compiled prefill + batched decode with slot management.

The engine owns a fixed-capacity decode batch (``max_batch`` slots, each
with a ``max_seq`` cache). Requests are prefetched one at a time (prompt
padded to a power-of-two bucket so the number of compiled prefill programs
stays small) and *inserted* into a free slot of the running batch cache —
the mechanism continuous batching (scheduler.py) is built on.

All hot functions are jitted once per (bucket) shape:
- ``_prefill_one``: prompt [1, bucket] -> (last logits, single-slot cache)
- ``_insert``: copy a single-slot cache into slot ``i`` of the batch cache
- ``_decode``: one step for all slots (+ sampling), inactive slots masked
- ``_chunk``: ``lax.scan`` over ``decode_chunk`` fused decode steps with
  on-device sampling and per-slot termination masks (EOS / token budget /
  ``max_seq`` capacity) — the scheduler syncs to host once per chunk
  instead of once per token.

The decode fast path is *sync-free*: the engine keeps the next input token
per slot on device (``_next_tok``). ``insert_request`` computes the first
generated token with an on-device argmax and returns it as an unforced
device scalar, so admitting a request never blocks the host on a
device->host read — the prefill dispatch overlaps the in-flight decode
chunk and the scheduler reads tokens at its single per-chunk sync point.

KV memory is either *contiguous* (each slot owns a ``max_seq`` cache —
memory scales with capacity) or *paged* (``paged=True``: a shared pool of
``kv_pool_blocks`` pages of ``page_size`` tokens, addressed through
per-slot block tables — memory scales with actual context). The engine
owns the page allocator host-side (free list + table mirror; device rows
are pushed asynchronously, never a sync): prefill allocates the prompt's
pages plus the first decode write's page, ``ensure_capacity`` secures one
page per upcoming KV write, and retire/cancel returns every page. Paging
applies to linear attention caches only; ring families (ssm / hybrid /
sliding-window) silently keep the linear layout.

Prompt accounting is two-track: ``_lengths`` / ``context_len`` are the
PHYSICAL cache lengths (ring families pad prompts to their bucket and
treat pads as context), while ``logical_len`` / ``kv_stats`` report what
the client actually sent — padding is never billed as usage.

``prefix_cache=True`` (paged engines only) layers a content-addressed
prefix cache (serving/prefix_cache.py) on the page allocator. Admission
then splits a prompt at the largest page boundary below its length:
the aligned *prefix* comes from cached pages when its chained hash
matches (refcount bumped, no prefill compute) or is prefilled and
registered, and the *tail* is force-fed through the fused decode path
(``_fill``) — one scan dispatch that writes the tail's KV and yields the
first-token logits. Cold and warm admissions thus share the exact same
numeric path for everything past the prefix boundary, which is what makes
a warm replay token-identical to its cold run. Shared and cache-
registered pages are READ-ONLY: the one write that can target one (the
full-hit replay of the last prompt token) copy-on-writes the page first,
and retire/cancel parks unreferenced cached pages in an LRU the allocator
evicts from before declaring the pool exhausted.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import mask_padded_vocab
from repro.serving.tracing import now as _now

F32 = jnp.float32


def _bucket(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


@dataclass
class GenerationResult:
    tokens: List[int]
    prompt_len: int
    steps: int
    finished: bool
    latency_s: float = 0.0
    # time-to-first-token, measured at the first host sync that revealed a
    # token (None on paths that don't time it, e.g. format_generation's
    # synthetic results) — lets the sync service report real TTFT
    first_token_s: Optional[float] = None


class GenerationEngine:
    """Single-host serving engine for one model asset."""

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_seq: int = 512, eos_id: Optional[int] = None,
                 decode_chunk: int = 8, paged: bool = False,
                 page_size: int = 16, kv_pool_blocks: Optional[int] = None,
                 prefix_cache: bool = False,
                 prefix_cache_pages: Optional[int] = None,
                 extra_inputs: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        # fused decode steps per host sync (compile-stable; per-slot budgets
        # stop individual sequences mid-chunk). Floored to a power of two
        # up front: the scheduler's budget alignment only ever uses pow2
        # lengths, so accepting e.g. 12 verbatim would silently run 8
        self.decode_chunk = 1 << (max(1, int(decode_chunk)).bit_length() - 1)
        # static per-request extra inputs (e.g. image embeds builder)
        self.extra_inputs = extra_inputs or {}
        # host-side summary of the most recent admission (prompt tokens,
        # prefix-cache hit tokens, pages allocated, COW) — read by the
        # scheduler's tracer immediately after insert_request
        self.last_admission: Optional[Dict[str, Any]] = None

        # Ring-cache families (sliding-window / hybrid local attention / SSM
        # state) left-pad prompts and wrap or accumulate their caches —
        # they keep the linear layout. A sliding window >= max_seq never
        # wraps, so such engines are plain linear caches (no bucket
        # padding charged, pageable). Paged KV applies to linear attention
        # caches only; asking for it elsewhere falls back silently (linear
        # stays the default for ring families).
        self._ring = (self.cfg.family in ("hybrid", "ssm")
                      or (self.cfg.sliding_window is not None
                          and self.cfg.sliding_window < max_seq))
        pageable = not self._ring and self.cfg.family != "audio"
        self.paged = bool(paged) and pageable
        if self.paged:
            if max_seq % page_size:
                raise ValueError(
                    f"page_size {page_size} must divide max_seq {max_seq}")
            self.page_size = page_size
            self._pages_per_slot = max_seq // page_size
            # default pool = same capacity as the contiguous layout; the
            # win is that admission and occupancy are charged per page in
            # use, and a smaller pool (oversubscription) is a valid config
            self.kv_pool_blocks = int(kv_pool_blocks) if kv_pool_blocks \
                else max_batch * self._pages_per_slot
            self._free_pool: List[int] = list(range(self.kv_pool_blocks))
            self._slot_blocks: List[List[int]] = [[] for _ in range(max_batch)]
            # host mirror of the device block table (sentinel = pool size)
            self._table = np.full((max_batch, self._pages_per_slot),
                                  self.kv_pool_blocks, np.int32)
            self._cache = model.init_cache(
                max_batch, max_seq, paged=(self.kv_pool_blocks, page_size))
            self._insert = jax.jit(self._insert_paged_impl,
                                   donate_argnums=(0,))
        else:
            self.page_size = 0
            self.kv_pool_blocks = 0
            self._free_pool = []
            self._slot_blocks = [[] for _ in range(max_batch)]
            self._cache = model.init_cache(max_batch, max_seq)
            self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        # prefix caching rides the paged layout (block tables are what make
        # cross-slot page sharing possible); asking for it elsewhere falls
        # back silently, like paged itself on ring families
        self.prefix_cache: Optional[PrefixCache] = None
        if self.paged and prefix_cache:
            self.prefix_cache = PrefixCache(
                self.page_size, max_unreferenced=prefix_cache_pages)
            # block-table references per pool page (1 = uniquely owned,
            # >1 = shared; shared or cache-registered pages are read-only)
            self._page_refs = np.zeros((self.kv_pool_blocks,), np.int32)
            # slots whose KV is keyed purely by token-ids — requests with
            # extra inputs (image embeds…) bypass the cache entirely
            self._slot_cacheable = [False] * max_batch
            self._fill_jit: Dict[int, Any] = {}
            self._copy_page = jax.jit(self._copy_page_impl,
                                      donate_argnums=(0,))
        self._lengths = np.zeros((max_batch,), np.int32)
        self._active = np.zeros((max_batch,), bool)
        # logical vs physical prompt accounting: ring families pad prompts
        # to their bucket and treat pads as context, so _lengths (physical,
        # cache bookkeeping) may exceed the user's prompt. Usage and stats
        # report the logical numbers.
        self._prompt_lens = np.zeros((max_batch,), np.int32)   # logical
        self._prefill_lens = np.zeros((max_batch,), np.int32)  # physical
        # device-resident next input token per slot (sync-free admission:
        # insert_request writes it with an on-device argmax, step_chunk
        # carries it forward — the host never has to know it)
        self._next_tok = jnp.zeros((max_batch,), jnp.int32)

        self._kv_bytes_per_token = self._bytes_per_token(self._cache)
        self._prefill_jit: Dict[int, Any] = {}
        self._decode = jax.jit(self._decode_impl)
        # one compiled scan per chunk length actually used (lazy, bounded
        # by decode_chunk): the scheduler aligns chunks to the earliest
        # completion, so short lengths recur and long ones amortize
        self._chunk_jit: Dict[int, Any] = {}
        self._first_tok = jax.jit(self._first_tok_impl)

    @staticmethod
    def _bytes_per_token(cache) -> int:
        """Device bytes one token of context costs across all layers (the
        unit for KV-memory accounting; 0 for constant-state SSM caches)."""
        if "k_pool" in cache:
            kp = cache["k_pool"]                   # [L, N, P, KV, hd]
            per_entry = int(np.prod(kp.shape[3:])) * kp.dtype.itemsize
            return 2 * kp.shape[0] * per_entry
        for key in ("k", "attn_k"):                # [L|nb, B, S, KV, hd]
            if key in cache:
                k = cache[key]
                per_entry = int(np.prod(k.shape[3:])) * k.dtype.itemsize
                return 2 * k.shape[0] * per_entry
        return 0

    # -- jitted internals ---------------------------------------------------

    def _prefill_impl(self, params, batch):
        return self.model.prefill(params, batch, cache_len=self.max_seq)

    def _insert_impl(self, batch_cache, one_cache, slot):
        """Copy a B=1 cache into slot ``slot`` of the batch cache.

        The batch axis of each leaf is located structurally: the first axis
        where the source is 1 and the destination is ``max_batch``. (Leading
        layer-stack dims match between src and dst, so they never trigger.)
        """
        def put(dst, src):
            if dst.ndim == 1:                       # lengths [B]
                return dst.at[slot].set(src[0])
            for ax in range(dst.ndim):
                if src.shape[ax] == 1 and dst.shape[ax] == self.max_batch:
                    idx = (slice(None),) * ax + (slot,)
                    return dst.at[idx].set(jnp.squeeze(src, ax))
            return dst
        return jax.tree.map(put, batch_cache, one_cache)

    def _insert_paged_impl(self, batch_cache, one_cache, table_row, slot):
        """Scatter a B=1 linear prefill cache into the slot's pool pages.

        ``table_row`` [pages_per_slot] holds the slot's pool page ids
        (sentinel ``kv_pool_blocks`` for pages past the prompt — their
        scatters drop). The prefill cache is always ``max_seq`` long, so it
        reshapes exactly into pages_per_slot pages.
        """
        nb, P = self._pages_per_slot, self.page_size

        def put_pool(pool, src):
            pages = jnp.squeeze(src, 1).reshape(
                src.shape[0], nb, P, *src.shape[3:])
            return pool.at[:, table_row].set(pages.astype(pool.dtype),
                                             mode="drop")

        cache = dict(batch_cache)
        cache["k_pool"] = put_pool(batch_cache["k_pool"], one_cache["k"])
        cache["v_pool"] = put_pool(batch_cache["v_pool"], one_cache["v"])
        cache["lengths"] = batch_cache["lengths"].at[slot].set(
            one_cache["lengths"][0])
        cache["block_table"] = batch_cache["block_table"].at[slot].set(
            table_row)
        return cache

    # -- paged pool management (host side; device work stays sync-free) -----

    def _alloc_blocks(self, slot: int, n: int) -> bool:
        """Move ``n`` pool pages to ``slot`` (all-or-nothing). With a
        prefix cache attached, unreferenced cached pages are LRU-evicted
        into the free list first — retained cache never shrinks the pool
        capacity admission can claim."""
        if self.prefix_cache is not None:
            while len(self._free_pool) < n:
                page = self.prefix_cache.pop_evictable()
                if page is None:
                    break
                self._free_pool.append(page)
        if len(self._free_pool) < n:
            return False
        start = len(self._slot_blocks[slot])
        for i in range(n):
            blk = self._free_pool.pop()
            self._slot_blocks[slot].append(blk)
            self._table[slot, start + i] = blk
            if self.prefix_cache is not None:
                self._page_refs[blk] = 1
        return True

    def _take_free_page(self) -> Optional[int]:
        """One pool page for a copy-on-write target (evicting from the
        prefix cache if the free list is dry); None when truly exhausted."""
        if not self._free_pool and self.prefix_cache is not None:
            page = self.prefix_cache.pop_evictable()
            if page is not None:
                self._free_pool.append(page)
        if not self._free_pool:
            return None
        blk = self._free_pool.pop()
        self._page_refs[blk] = 1
        return blk

    def _decref(self, blk: int):
        """Drop one block-table reference to ``blk``. The last reference
        frees the page — unless it is cache-registered, where it parks as
        an LRU eviction candidate instead (cap overflow evicts to free)."""
        self._page_refs[blk] -= 1
        assert self._page_refs[blk] >= 0, f"page {blk} refcount underflow"
        if self._page_refs[blk] == 0:
            if self.prefix_cache.contains_page(blk):
                self._free_pool.extend(
                    self.prefix_cache.release_page(blk))
            else:
                self._free_pool.append(blk)

    def _page_writable(self, blk: int) -> bool:
        """A page may take KV writes only while it is uniquely owned and
        not content-addressed: a shared page backs other slots' context,
        and a registered page backs the cache's hash -> content promise."""
        return (self._page_refs[blk] == 1
                and not self.prefix_cache.contains_page(blk))

    def _make_writable(self, slot: int, pos: int) -> bool:
        """Copy-on-write guard for the page holding position ``pos`` of
        ``slot``: shared / cache-registered pages are read-only, so the
        first write into one copies its content into a fresh page, repoints
        the slot's table entry, and drops the shared reference. Returns
        False when no page can be obtained for the copy (pool exhausted —
        the caller retires the slot cleanly)."""
        pi = pos // self.page_size
        if pi >= len(self._slot_blocks[slot]):
            return True                     # next write page not allocated yet
        blk = self._slot_blocks[slot][pi]
        if self._page_writable(blk):
            return True
        fresh = self._take_free_page()
        if fresh is None:
            return False
        self._cache = self._copy_page(
            self._cache, jnp.asarray(blk, jnp.int32),
            jnp.asarray(fresh, jnp.int32))
        self._slot_blocks[slot][pi] = fresh
        self._table[slot, pi] = fresh
        self._push_table_row(slot)
        self._decref(blk)
        self.prefix_cache.cow_copies += 1
        return True

    def _copy_page_impl(self, cache, src, dst):
        """Device-side pool page copy (all layers, k and v) — an async
        dispatch like every other cache op, never a host sync."""
        cache = dict(cache)
        cache["k_pool"] = cache["k_pool"].at[:, dst].set(
            cache["k_pool"][:, src])
        cache["v_pool"] = cache["v_pool"].at[:, dst].set(
            cache["v_pool"][:, src])
        return cache

    def _push_table_row(self, slot: int):
        """Mirror the slot's host table row to the device cache (a tiny
        async host->device transfer — never a sync)."""
        self._cache["block_table"] = self._cache["block_table"].at[slot].set(
            jnp.asarray(self._table[slot]))

    def free_blocks(self) -> int:
        """Unallocated pool pages (0 for contiguous engines)."""
        return len(self._free_pool)

    def available_blocks(self) -> int:
        """Pool pages admission may claim: the free list plus every
        unreferenced cached page the allocator could evict."""
        avail = len(self._free_pool)
        if self.prefix_cache is not None:
            avail += self.prefix_cache.evictable()
        return avail

    def blocks_in_use(self) -> int:
        """Pages referenced by live slots — shared pages count ONCE, and
        cache-retained (unreferenced) pages are not live context."""
        used = self.kv_pool_blocks - len(self._free_pool)
        if self.prefix_cache is not None:
            used -= self.prefix_cache.evictable()
        return used

    def _prompt_page_plan(self, prompt: List[int]
                          ) -> Tuple[int, List[int], int]:
        """(total pages the seated prompt references, cached pages backing
        its longest hashed prefix, extra pages copy-on-write will draw).
        The COW page appears exactly when the *whole* prompt is cached:
        the last prompt token must be replayed for its logits, and its KV
        write targets the final shared page."""
        n = len(prompt)
        total = -(-(n + 1) // self.page_size)
        hits = self.prefix_cache.match(prompt, peek=True)
        cow = 1 if len(hits) * self.page_size >= n else 0
        return total, hits, cow

    def blocks_for_prompt(self, prompt) -> int:
        """Pool pages admission must see claimable before taking this
        prompt: its prefill pages plus room for the first decode write.
        Accepts a token list (a prefix-cached engine then charges only the
        pages the cache cannot seat) or a bare length (full charge — used
        for worst-case bounds and requests with extra inputs, which bypass
        the cache)."""
        if isinstance(prompt, (int, np.integer)):
            n, toks = int(prompt), None
        else:
            toks = list(prompt)
            n = len(toks)
        true_len = _bucket(n) if self._ring else n
        total = -(-(true_len + 1) // self.page_size)
        if toks is None or self.prefix_cache is None:
            return total
        _, hits, cow = self._prompt_page_plan(toks)
        return total - len(hits) + cow

    def can_admit(self, prompt) -> bool:
        """Block-aware admission gate: beyond :meth:`fits_prompt`, a paged
        engine also needs enough claimable pool pages for the prompt.
        Like :meth:`blocks_for_prompt`, accepts a token list or a length;
        with a token list a prefix-cached engine charges only non-cached
        pages — but never counts the prompt's own prospective hits as
        evictable headroom."""
        if isinstance(prompt, (int, np.integer)):
            n, toks = int(prompt), None
        else:
            toks = list(prompt)
            n = len(toks)
        if not self.fits_prompt(n):
            return False
        if not self.paged:
            return True
        if toks is None or self.prefix_cache is None:
            return self.available_blocks() >= self.blocks_for_prompt(n)
        total, hits, cow = self._prompt_page_plan(toks)
        avail = (len(self._free_pool)
                 + self.prefix_cache.evictable_excluding(hits))
        return avail >= total - len(hits) + cow

    def ensure_capacity(self, slot: int, want: int) -> int:
        """Secure write headroom for up to ``want`` more KV entries on
        ``slot``, allocating pool pages as needed and available. Returns
        the writes actually available — may be < ``want`` when the pool is
        tight, 0 when the slot cannot take a single further write (the
        caller retires it). Contiguous engines just report the remaining
        ``max_seq`` headroom. Idempotent and allocation-only (pages free on
        retire, never mid-flight)."""
        length = int(self._lengths[slot])
        phys = self.max_seq - length
        if not self.paged:
            return max(0, min(want, phys))
        want = min(want, phys)
        have = len(self._slot_blocks[slot]) * self.page_size - length
        dirty = False
        while have < want and self.available_blocks() \
                and len(self._slot_blocks[slot]) < self._pages_per_slot:
            self._alloc_blocks(slot, 1)
            have += self.page_size
            dirty = True
        if dirty:
            self._push_table_row(slot)
        if self.prefix_cache is not None and want > 0 and have > 0:
            # read-only page invariant: the next KV write lands at
            # ``length`` — if that position sits in a shared or cache-
            # registered page, copy-on-write it now (steady-state this
            # never fires: insert COWs the one replay write, and decode
            # writes land past every shared page — but direct step()
            # drivers and the property harness exercise it)
            if not self._make_writable(slot, length):
                return 0
        return max(0, min(want, have))

    def _first_tok_impl(self, logits, next_tok, slot):
        """First generated token from prefill logits (greedy over the
        logical vocab), written into the device next-token buffer."""
        masked = mask_padded_vocab(logits[0], self.cfg.vocab_size)
        first = jnp.argmax(masked).astype(jnp.int32)
        return first, next_tok.at[slot].set(first)

    def _sample(self, logits, rng, temperature):
        """Per-slot-temperature sampling: rows at 0 take the greedy argmax."""
        masked = mask_padded_vocab(logits, self.cfg.vocab_size)
        greedy = jnp.argmax(masked, axis=-1).astype(jnp.int32)
        scaled = masked / jnp.maximum(temperature, 1e-6)[:, None]
        sampled = jax.random.categorical(rng, scaled, axis=-1) \
            .astype(jnp.int32)
        return jnp.where(temperature > 0, sampled, greedy)

    def _decode_impl(self, params, cache, tokens, rng, temperature, active):
        """One decode step; ``temperature`` is a per-slot [max_batch]
        vector so mixed-temperature batches don't interfere — each row
        samples at its own temperature. The fixed vector shape keeps the
        step compile-stable. ``active`` gates both sampling output and the
        per-slot cache-length advance (a slot at ``max_seq`` capacity must
        not write past its cache)."""
        logits, cache = self.model.decode_step(params, cache, tokens,
                                               active=active)
        nxt = self._sample(logits, rng, temperature)
        nxt = jnp.where(active, nxt, 0)
        return nxt, cache

    def _runnable(self, tok, left, lengths, run):
        """Per-slot continuation mask: a slot keeps decoding while it has
        token budget, cache capacity for the next KV write, and its input
        token is not EOS."""
        run = run & (left > 0) & (lengths < self.max_seq)
        if self.eos_id is not None:
            run = run & (tok != self.eos_id)
        return run

    def _unpage(self, cache):
        """Gather the block-table view into a contiguous linear cache
        (``[L, B, S, KV, hd]``). Sentinel table entries clamp to an
        arbitrary page whose data sits past the owner's length — masked."""
        bt = jnp.clip(cache["block_table"], 0, self.kv_pool_blocks - 1)

        def gather(pool):
            g = pool[:, bt]                       # [L, B, nb, P, KV, hd]
            return g.reshape(g.shape[0], g.shape[1], -1, *g.shape[4:])

        return {"lengths": cache["lengths"],
                "k": gather(cache["k_pool"]), "v": gather(cache["v_pool"])}

    def _repage(self, cache, work):
        """Scatter a chunk's updated contiguous view back into the pool.
        Unallocated (sentinel) pages scatter out of bounds and drop, so
        writes past a slot's allocation never touch foreign pages."""
        table = cache["block_table"]
        nb = table.shape[1]

        def scatter(pool, kc):
            pages = kc.reshape(kc.shape[0], kc.shape[1], nb, self.page_size,
                               *kc.shape[3:])
            return pool.at[:, table].set(pages.astype(pool.dtype),
                                         mode="drop")

        return dict(cache,
                    k_pool=scatter(cache["k_pool"], work["k"]),
                    v_pool=scatter(cache["v_pool"], work["v"]),
                    lengths=work["lengths"])

    def _chunk_impl(self, k, params, cache, next_tok, rng, temperature,
                    budgets, active):
        """Fused multi-step decode: ``lax.scan`` over ``k`` steps with
        on-device sampling and termination.

        Per step, slots whose mask is off keep their input token and do not
        advance their cache length; the step's KV/state writes for them land
        past their valid length (invisible) and are overwritten on the next
        insert. Returns (cache, next_tok, tokens [B, K], emitted [B, K])
        where ``emitted[b]`` is a contiguous prefix mask — once a slot
        terminates it never resumes within the chunk.

        Paged caches on the ORACLE backend are translated at the CHUNK
        boundary: the block table is fixed across a chunk (the scheduler
        secures every page before dispatch), so the pages gather into a
        contiguous working view once, the whole chunk runs on the linear
        fast path, and the touched pages scatter back once —
        layout-translation cost amortizes over the chunk exactly like the
        host sync does. On the Pallas backends no translation happens at
        all: each step runs the block-table decode kernel against the pool
        in place. (The backend is baked in at trace time like every other
        kernel dispatch; engines are built per backend.)

        RNG parity contract (property-tested): step ``i`` uses ``sub_i``
        from the chain ``rng_i, sub_i = split(rng_{i-1})`` — identical to
        driving ``decode_chunk`` single ``step()`` calls with the same
        chain, so fused and stepwise decode are token-identical.
        """
        from repro.kernels import ops as _kops
        translate = "k_pool" in cache and _kops.get_backend() == "ref"
        work = self._unpage(cache) if translate else cache

        def body(carry, _):
            work, tok, rng, run, left = carry
            rng, sub = jax.random.split(rng)
            logits, work = self.model.decode_step(params, work, tok,
                                                  active=run)
            nxt = self._sample(logits, sub, temperature)
            # dead slots hold their token: keeps the carry stable and the
            # (batch-coupled, e.g. MoE-capacity) compute deterministic
            nxt = jnp.where(run, nxt, tok)
            left = left - run.astype(jnp.int32)
            run_next = self._runnable(nxt, left, work["lengths"], run)
            return (work, nxt, rng, run_next, left), (nxt, run)

        run0 = self._runnable(next_tok, budgets, work["lengths"], active)
        (work, tok, _, _, _), (toks, emitted) = jax.lax.scan(
            body, (work, next_tok, rng, run0, budgets), None, length=k)
        cache = self._repage(cache, work) if translate else work
        return (cache, tok,
                jnp.swapaxes(toks, 0, 1), jnp.swapaxes(emitted, 0, 1))

    def _fill_impl(self, k, params, cache, tokens, count, start, slot,
                   next_tok):
        """Force-feed ``count`` prompt tokens into ``slot`` as one fused
        scan of ``k`` (>= count, compile-stable pow2) decode steps starting
        at position ``start`` — the prefix-cache tail path. Each step
        writes one KV entry exactly like regular decode (so the tail's
        pages end up byte-identical to decode-produced ones), and the
        final fed token's logits yield the first generated token, written
        into the device next-token buffer (sync-free admission, same
        contract as ``_first_tok``).

        Other slots run masked (inactive): their lengths hold and their
        KV writes land past their valid length, the same invisible-write
        convention the chunk path uses. On the ORACLE backend the paged
        cache translates at the fill boundary exactly like ``_chunk_impl``
        — the block table is fixed across the fill (every page was secured
        before dispatch), and shared read-only pages scatter back the very
        bytes they gathered (the linear steps only write at this slot's
        positions), so the round-trip never mutates them.
        """
        from repro.kernels import ops as _kops
        translate = "k_pool" in cache and _kops.get_backend() == "ref"
        cache = dict(cache)
        cache["lengths"] = cache["lengths"].at[slot].set(start)
        work = self._unpage(cache) if translate else cache
        mine = jnp.arange(self.max_batch) == slot

        def body(carry, tok):
            work, i = carry
            active = mine & (i < count)
            tok_vec = jnp.where(mine, tok, 0).astype(jnp.int32)
            logits, work = self.model.decode_step(params, work, tok_vec,
                                                  active=active)
            return (work, i + 1), logits[slot]

        (work, _), logit_seq = jax.lax.scan(
            body, (work, jnp.int32(0)), tokens, length=k)
        cache = self._repage(cache, work) if translate else work
        masked = mask_padded_vocab(logit_seq[count - 1], self.cfg.vocab_size)
        first = jnp.argmax(masked).astype(jnp.int32)
        return cache, next_tok.at[slot].set(first), first

    def _fill(self, tail: List[int], start: int, slot: int) -> jax.Array:
        """Dispatch the fused tail fill; returns the first-token scalar."""
        k = _bucket(len(tail), minimum=1)
        if k not in self._fill_jit:
            self._fill_jit[k] = jax.jit(partial(self._fill_impl, k),
                                        donate_argnums=(1,))
        padded = np.zeros((k,), np.int32)
        padded[:len(tail)] = tail
        self._cache, self._next_tok, first = self._fill_jit[k](
            self.params, self._cache, jnp.asarray(padded),
            jnp.asarray(len(tail), jnp.int32),
            jnp.asarray(start, jnp.int32),
            jnp.asarray(slot, jnp.int32), self._next_tok)
        return first

    # -- public API ------------------------------------------------------------

    def fits_prompt(self, n: int) -> bool:
        """Whether an ``n``-token prompt is admissible: its padding bucket
        must not exceed ``max_seq`` AND its *physical* prefill length
        (the bucket itself for ring families, which treat pads as context)
        must leave at least one KV write of generation headroom. A prompt
        that fills the cache would burn a prefill + slot only to retire
        with nothing generated beyond the prefill token — callers reject
        it at validation time (``PROMPT_TOO_LONG``) instead."""
        bucket = _bucket(n)
        if bucket > self.max_seq:
            return False
        true_len = bucket if self._ring else n
        return true_len < self.max_seq

    def max_prompt_len(self) -> int:
        """Longest admissible prompt in tokens — consistent with
        :meth:`fits_prompt` by construction, so a caller that truncates to
        this length is never rejected. Ring families are bounded by the
        padding bucket (largest bucket strictly below ``max_seq``); linear
        engines by ``max_seq - 1`` — unless ``max_seq`` is not a bucket
        size itself, where the bound drops to the largest bucket that
        still fits (e.g. max_seq=100 admits at most 64: a 99-token prompt
        would pad to a 128 bucket)."""
        if not self._ring:
            n = self.max_seq - 1
            if n > 0 and _bucket(n) <= self.max_seq:
                return n
        b = 16                       # _bucket's minimum
        if b > self.max_seq or (self._ring and b >= self.max_seq):
            return 0
        limit = self.max_seq - 1 if self._ring else self.max_seq
        while b * 2 <= limit:
            b *= 2
        return b

    def free_slots(self) -> List[int]:
        return [i for i in range(self.max_batch) if not self._active[i]]

    def context_len(self, slot: int) -> int:
        """Physical cache length of ``slot`` (cache bookkeeping: includes
        ring-family padding)."""
        return int(self._lengths[slot])

    def logical_len(self, slot: int) -> int:
        """User-visible context of ``slot``: prompt tokens as submitted
        plus generated tokens — ring-family padding is not billed."""
        return int(self._prompt_lens[slot]
                   + (self._lengths[slot] - self._prefill_lens[slot]))

    def active_logical_tokens(self) -> int:
        gen = self._lengths - self._prefill_lens
        return int(((self._prompt_lens + gen) * self._active).sum())

    def capacity_left(self, slot: int) -> int:
        """KV writes remaining before ``slot`` cannot decode another token.
        Pool-aware on paged engines: bounded by ``max_seq`` AND by the
        slot's allocated pages plus what the shared pool could still
        provide."""
        left = int(self.max_seq - self._lengths[slot])
        if self.paged:
            have = (len(self._slot_blocks[slot]) * self.page_size
                    - int(self._lengths[slot]))
            left = min(left, have + self.available_blocks() * self.page_size)
        return max(0, left)

    def kv_stats(self) -> Dict[str, Any]:
        """KV memory accounting. A contiguous cache charges the full
        ``max_seq`` per occupied slot (memory scales with *capacity*); a
        paged cache charges the pool pages actually allocated (memory
        scales with *actual context*). ``active_tokens`` is the logical
        context — ring-family padding is not billed as context."""
        bpt = self._kv_bytes_per_token
        active = int(self._active.sum())
        logical = self.active_logical_tokens()
        if self.paged:
            used = self.blocks_in_use()
            in_use = used * self.page_size * bpt
            out: Dict[str, Any] = {
                "paged": True, "page_size": self.page_size,
                "pool_blocks": self.kv_pool_blocks,
                "blocks_in_use": used,
                "free_blocks": len(self._free_pool),
            }
            if self.prefix_cache is not None:
                # cache-retained pages are claimable, not live context
                out["cached_blocks"] = self.prefix_cache.evictable()
                out["prefix_cache"] = self.prefix_stats()
        else:
            in_use = active * self.max_seq * bpt
            out = {"paged": False}
        out.update(
            active_slots=active,
            active_tokens=logical,
            kv_bytes_per_token=bpt,
            kv_bytes_in_use=int(in_use),
            kv_bytes_per_active_token=(round(in_use / logical, 1)
                                       if logical else 0.0),
        )
        return out

    def prefix_stats(self) -> Optional[Dict[str, int]]:
        """Prefix-cache counters plus the instantaneous shared-page count
        (pages referenced by more than one block table); None when prefix
        caching is off."""
        if self.prefix_cache is None:
            return None
        s = self.prefix_cache.stats()
        s["shared_pages"] = int((self._page_refs > 1).sum())
        return s

    def check_pool_invariants(self, *, device: bool = True):
        """Audit the page-allocator partition (test hook; ``device=True``
        also syncs the device block table against the host mirror).

        Every pool page must be exactly one of:
        - free (on the free list, unreferenced, not cached),
        - live (referenced by >= 1 block tables, refcount == the number of
          table references; uniquely owned when 1, shared when > 1),
        - cache-retained (registered, zero references, parked in the LRU).

        In particular a freed page can never still be referenced from any
        table — the no-use-after-free half of the COW/refcount contract.
        """
        assert self.paged, "invariant audit is for paged engines"
        refs: Dict[int, int] = {}
        for s in range(self.max_batch):
            blocks = self._slot_blocks[s]
            for i, pg in enumerate(blocks):
                assert 0 <= pg < self.kv_pool_blocks, (s, i, pg)
                assert self._table[s, i] == pg, \
                    f"host table desync at slot {s} page {i}"
                refs[pg] = refs.get(pg, 0) + 1
            assert (self._table[s, len(blocks):]
                    == self.kv_pool_blocks).all(), \
                f"slot {s} table not sentinel past its allocation"
        free = set(self._free_pool)
        assert len(free) == len(self._free_pool), "double-freed page"
        assert not free & set(refs), \
            f"freed pages still referenced: {sorted(free & set(refs))}"
        if self.prefix_cache is not None:
            for pg in range(self.kv_pool_blocks):
                assert int(self._page_refs[pg]) == refs.get(pg, 0), \
                    (f"page {pg} refcount {int(self._page_refs[pg])} != "
                     f"{refs.get(pg, 0)} table references")
            lru = set(self.prefix_cache.unreferenced_pages())
            cached = set(self.prefix_cache.cached_pages())
            assert lru <= cached
            assert not lru & free and not lru & set(refs)
            # a registered page with no references must be evictable
            assert cached - set(refs) == lru, \
                "unreferenced cached page missing from the LRU"
            covered = free | set(refs) | lru
        else:
            covered = free | set(refs)
        assert covered == set(range(self.kv_pool_blocks)), \
            f"leaked pages: {sorted(set(range(self.kv_pool_blocks)) - covered)}"
        if device:
            dev = np.asarray(self._cache["block_table"])
            assert (dev == self._table).all(), "device table desync"

    def insert_request(self, prompt: List[int], slot: int,
                       extra: Optional[Dict[str, Any]] = None) -> jax.Array:
        """Prefill ``prompt`` into ``slot``; returns the first generated
        token as an *unforced* device scalar (greedy argmax over the prefill
        logits, computed on device). Callers defer the host read to their
        next sync point — admission never stalls the decode loop."""
        assert not self._active[slot], f"slot {slot} busy"
        bucket = _bucket(len(prompt))
        if bucket > self.max_seq:
            raise ValueError(f"prompt {len(prompt)} exceeds max_seq {self.max_seq}")
        # prefix-cached admission applies only to requests whose KV is a
        # pure function of the token ids: anything carrying extra inputs
        # (image embeds, audio frames) takes the plain paged path and its
        # pages are never registered
        if (self.prefix_cache is not None and not extra
                and not self.extra_inputs):
            return self._insert_cached(list(prompt), slot)
        if self.prefix_cache is not None:
            self._slot_cacheable[slot] = False
        if bucket not in self._prefill_jit:
            self._prefill_jit[bucket] = jax.jit(self._prefill_impl)
        # Ring-cache families (sliding-window / hybrid local attention) need
        # contiguous positions, so their prompts are LEFT-padded and pads are
        # treated as context. Linear caches RIGHT-pad; causal masking keeps
        # pads out of real-token attention and decode masks by true length.
        # (SSM states are cumulative too, so stateful families all left-pad.)
        ring = self._ring
        padded = np.zeros((1, bucket), np.int32)
        if ring:
            padded[0, bucket - len(prompt):] = prompt
            true_len = bucket
        else:
            padded[0, :len(prompt)] = prompt
            true_len = len(prompt)
        batch = {"tokens": jnp.asarray(padded),
                 "prompt_lengths": jnp.asarray([true_len], np.int32)}
        for k, v in (extra or self.extra_inputs).items():
            batch[k] = v
        if self.paged:
            # allocate the prefill's pages — plus the page the FIRST decode
            # write lands in, so a fresh admission can never be starved by
            # co-tenants before its first chunk — BEFORE dispatching
            # compute; the scheduler gates admission on can_admit so this
            # only trips for direct callers outrunning the pool.
            # blocks_for_prompt is the ONE statement of this reservation
            # rule: the admission gate and the allocator must never diverge
            need = self.blocks_for_prompt(len(prompt))
            if not self._alloc_blocks(slot, need):
                raise RuntimeError(
                    f"KV pool exhausted: prompt needs {need} pages, "
                    f"{len(self._free_pool)} of {self.kv_pool_blocks} free")
        # host mirrors flip BEFORE the (possibly compiling) prefill
        # dispatch: stats readers on other threads must never observe
        # allocated pages without an owner
        self._lengths[slot] = true_len
        self._prompt_lens[slot] = len(prompt)
        self._prefill_lens[slot] = true_len
        self._active[slot] = True
        try:
            logits, one_cache = self._prefill_jit[bucket](self.params, batch)
            if self.paged:
                self._cache = self._insert(
                    self._cache, one_cache, jnp.asarray(self._table[slot]),
                    jnp.asarray(slot, jnp.int32))
            else:
                self._cache = self._insert(self._cache, one_cache,
                                           jnp.asarray(slot, jnp.int32))
            first, self._next_tok = self._first_tok(
                logits, self._next_tok, jnp.asarray(slot, jnp.int32))
        except Exception:
            self.release_slot(slot)   # no orphaned slot or leaked pages
            raise
        # host-side admission summary for observability (the scheduler's
        # tracer reads it right after insert — never a device value)
        self.last_admission = {
            "prompt_tokens": len(prompt), "cached_hit_tokens": 0,
            "pages_allocated": need if self.paged else 0, "cow": False}
        return first

    def _insert_cached(self, prompt: List[int], slot: int) -> jax.Array:
        """Prefix-cached admission. The prompt splits at page boundaries:

        - ``[0, hit_len)`` — the longest cached prefix: those pool pages
          are installed into the slot's block table with a refcount bump
          and NO compute (the prefill the cache absorbed);
        - ``[hit_len, n)`` — the miss region: on a cold miss the aligned
          part comes from the regular bucketed prefill, then the tail (a
          partial-hit miss region decode-fills entirely — prefill cannot
          start mid-sequence) is force-fed through the fused decode scan
          (:meth:`_fill`), which also yields the first generated token.

        Cold and warm admissions share the fill path for everything past
        the prefix boundary, so a warm replay of a seen prompt is token-
        identical to its cold run by construction (property-tested). When
        the WHOLE prompt is cached (page-aligned), the last prompt token
        is replayed for its logits; its KV write targets the final shared
        page, which copy-on-writes first — cached bytes never mutate.
        Freshly computed full prompt pages register immediately, so
        co-batched duplicates admitted later the same tick already hit.
        """
        n = len(prompt)
        P = self.page_size
        cache = self.prefix_cache
        total = -(-(n + 1) // P)          # prompt pages + first decode write
        hits = cache.match(prompt)
        hit_len = len(hits) * P
        assert not self._slot_blocks[slot], f"slot {slot} holds pages"
        for i, pg in enumerate(hits):
            self._slot_blocks[slot].append(pg)
            self._table[slot, i] = pg
            self._page_refs[pg] += 1
            cache.ref_page(pg)
        if not self._alloc_blocks(slot, total - len(hits)):
            self.release_slot(slot)       # drop the shared refs taken above
            raise RuntimeError(
                f"KV pool exhausted: prompt needs {total - len(hits)} new "
                f"pages, {self.available_blocks()} of "
                f"{self.kv_pool_blocks} claimable")
        self._push_table_row(slot)
        # host mirrors flip BEFORE the dispatches, same rule as the plain
        # path (paged prompts are linear: logical == physical == n)
        self._lengths[slot] = n
        self._prompt_lens[slot] = n
        self._prefill_lens[slot] = n
        self._active[slot] = True
        self._slot_cacheable[slot] = True
        try:
            if hit_len >= n:              # full hit: replay the last token
                start = n - 1
                if not self._make_writable(slot, start):
                    raise RuntimeError(
                        "KV pool exhausted: no page for the replay "
                        "copy-on-write")
            elif not hits and n - 1 >= P:
                # cold miss: aligned prefix through the regular prefill
                start = ((n - 1) // P) * P
                pb = _bucket(start)
                if pb not in self._prefill_jit:
                    self._prefill_jit[pb] = jax.jit(self._prefill_impl)
                padded = np.zeros((1, pb), np.int32)
                padded[0, :start] = prompt[:start]
                batch = {"tokens": jnp.asarray(padded),
                         "prompt_lengths": jnp.asarray([start], np.int32)}
                _, one_cache = self._prefill_jit[pb](self.params, batch)
                self._cache = self._insert(
                    self._cache, one_cache, jnp.asarray(self._table[slot]),
                    jnp.asarray(slot, jnp.int32))
            else:                         # partial hit (or tiny prompt)
                start = hit_len
            first = self._fill(prompt[start:], start, slot)
            keys = cache.chain_keys(prompt)
            for i in range(len(hits), n // P):
                cache.register(keys[i], self._slot_blocks[slot][i])
        except Exception:
            self.release_slot(slot)   # no orphaned slot or leaked pages
            raise
        # warm-vs-cold is distinguishable here: hit tokens were installed
        # by reference, only the remainder paid pages/compute
        self.last_admission = {
            "prompt_tokens": n, "cached_hit_tokens": min(hit_len, n),
            "pages_allocated": total - len(hits), "cow": hit_len >= n}
        return first

    def release_slot(self, slot: int, tokens: Optional[List[int]] = None):
        """Retire ``slot`` and return its KV pages.

        ``tokens`` (prompt + generated, as fed) lets a prefix-cached
        engine register the slot's fully-decoded pages before the
        references drop — multi-turn continuations then hit the whole
        previous exchange, not just the original prompt. Pages whose
        chain key is already cached (e.g. the shared prefix itself)
        simply skip. On the last reference, cache-registered pages park
        in the LRU free-candidate list; everything else frees."""
        self._active[slot] = False
        if not (self.paged and self._slot_blocks[slot]):
            return
        if self.prefix_cache is None:
            # free-on-retire: every page returns to the shared pool. The
            # sentinel row must reach the DEVICE table too: an inactive
            # slot still executes (masked) decode writes, and a stale row
            # would alias pages that now belong to another slot.
            self._free_pool.extend(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self._table[slot, :] = self.kv_pool_blocks
            self._push_table_row(slot)
            return
        if tokens is not None and self._slot_cacheable[slot]:
            # cache-eligible: pages fully covered by KV actually written
            # (positions [0, length)), keyed by the tokens that fed them
            full = min(int(self._lengths[slot]), len(tokens)) \
                // self.page_size
            keys = self.prefix_cache.chain_keys(
                tokens[:full * self.page_size])
            for i, key in enumerate(keys):
                self.prefix_cache.register(key, self._slot_blocks[slot][i])
        for pg in self._slot_blocks[slot]:
            self._decref(pg)
        self._slot_blocks[slot] = []
        self._slot_cacheable[slot] = False
        self._table[slot, :] = self.kv_pool_blocks
        self._push_table_row(slot)

    def reset(self):
        """Rebuild every piece of mutable serving state from scratch —
        the supervision layer's recovery hammer after repeated engine
        faults or a dead worker, when device caches, the page pool, and
        compiled programs are all suspect.

        Reconstructs the KV cache (and pool/table/refcounts on paged
        engines), clears the prefix cache, zeroes the host length/active
        mirrors, and drops every jitted callable so programs recompile
        clean. Weights (``params``) are immutable and survive. All active
        slots are abandoned: callers quarantine their requests first
        (``ContinuousBatchingScheduler.quarantine_active``); queued work
        never touched the engine and rides through untouched."""
        max_batch, max_seq = self.max_batch, self.max_seq
        if self.paged:
            self._free_pool = list(range(self.kv_pool_blocks))
            self._slot_blocks = [[] for _ in range(max_batch)]
            self._table = np.full((max_batch, self._pages_per_slot),
                                  self.kv_pool_blocks, np.int32)
            self._cache = self.model.init_cache(
                max_batch, max_seq,
                paged=(self.kv_pool_blocks, self.page_size))
            self._insert = jax.jit(self._insert_paged_impl,
                                   donate_argnums=(0,))
        else:
            self._free_pool = []
            self._slot_blocks = [[] for _ in range(max_batch)]
            self._cache = self.model.init_cache(max_batch, max_seq)
            self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        if self.prefix_cache is not None:
            self.prefix_cache = PrefixCache(
                self.page_size,
                max_unreferenced=self.prefix_cache.max_unreferenced)
            self._page_refs = np.zeros((self.kv_pool_blocks,), np.int32)
            self._slot_cacheable = [False] * max_batch
            self._fill_jit = {}
            self._copy_page = jax.jit(self._copy_page_impl,
                                      donate_argnums=(0,))
        self._lengths = np.zeros((max_batch,), np.int32)
        self._active = np.zeros((max_batch,), bool)
        self._prompt_lens = np.zeros((max_batch,), np.int32)
        self._prefill_lens = np.zeros((max_batch,), np.int32)
        self._next_tok = jnp.zeros((max_batch,), jnp.int32)
        self._prefill_jit = {}
        self._decode = jax.jit(self._decode_impl)
        self._chunk_jit = {}
        self._first_tok = jax.jit(self._first_tok_impl)
        self.last_admission = None

    def step(self, tokens: np.ndarray, rng, temperature=0.0):
        """One decode step for the whole batch. tokens [max_batch] int32;
        ``temperature`` is a scalar (applied to every slot) or a per-slot
        [max_batch] vector. Slots whose cache is full (length == max_seq,
        or — paged — no page obtainable for the next write) are masked:
        they emit 0 and do not advance — lengths never grow past the
        writable cache."""
        writable = self._active & (self._lengths < self.max_seq)
        if self.paged:
            for i in np.flatnonzero(writable):
                if self.ensure_capacity(int(i), 1) < 1:
                    writable[i] = False
        active = jnp.asarray(writable)
        temps = np.broadcast_to(np.asarray(temperature, np.float32),
                                (self.max_batch,))
        nxt, self._cache = self._decode(
            self.params, self._cache, jnp.asarray(tokens, jnp.int32), rng,
            jnp.asarray(temps, F32), active)
        self._lengths[writable] += 1
        return np.asarray(nxt)

    def step_chunk(self, rng, temperature, budgets, k: Optional[int] = None
                   ) -> Tuple[jax.Array, jax.Array]:
        """Dispatch one fused chunk of ``k`` (default ``decode_chunk``)
        decode steps.

        ``budgets`` [max_batch] int32 = tokens each slot may still emit
        (0 for free slots). Input tokens come from the device-resident
        ``_next_tok`` buffer (written by ``insert_request`` and the
        previous chunk), so no host state crosses to the device. Callers
        (the scheduler) pass ``k = min(decode_chunk, earliest remaining
        budget)`` so a chunk never runs masked steps past the first
        completion — short requests sync at per-token cadence, long
        co-batches amortize the full chunk.

        Returns unforced device arrays ``(tokens [B, k], emitted [B, k])``;
        the caller reads both in ONE host sync and then calls
        :meth:`commit_chunk` with the per-slot emission counts.
        """
        # k is the caller's explicit choice (the scheduler budget-aligns
        # it); decode_chunk is only the default
        k = self.decode_chunk if k is None else max(1, int(k))
        if k not in self._chunk_jit:
            self._chunk_jit[k] = jax.jit(partial(self._chunk_impl, k))
        if self.paged:
            # every budgeted write this chunk needs an allocated page
            # BEFORE dispatch (the device cannot allocate); clamping the
            # budget to the secured headroom freezes a starved slot at a
            # page boundary exactly like a max_seq-full one. The scheduler
            # pre-ensures and retires starved requests — this second call
            # is an idempotent no-op there and a guard for direct callers.
            budgets = np.asarray(budgets, np.int32).copy()
            for i in np.flatnonzero(self._active & (budgets > 0)):
                budgets[i] = self.ensure_capacity(int(i),
                                                  min(k, int(budgets[i])))
        temps = np.broadcast_to(np.asarray(temperature, np.float32),
                                (self.max_batch,))
        self._cache, self._next_tok, toks, emitted = self._chunk_jit[k](
            self.params, self._cache, self._next_tok, rng,
            jnp.asarray(temps, F32), jnp.asarray(budgets, jnp.int32),
            jnp.asarray(self._active))
        return toks, emitted

    def commit_chunk(self, emitted_counts: np.ndarray):
        """Fold a chunk's per-slot emission counts into the host-side
        length mirror (each emitted token wrote exactly one KV/state entry)."""
        self._lengths += np.asarray(emitted_counts, np.int32)

    # -- convenience: synchronous batch generation ------------------------------

    def generate(self, prompts: List[List[int]], *, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 extras: Optional[List[Dict[str, Any]]] = None,
                 ) -> List[GenerationResult]:
        """Generate for up to ``max_batch`` prompts at once (convenience path;
        the scheduler drives the slot API directly for continuous batching)."""
        assert len(prompts) <= self.max_batch
        t0 = _now()
        rng = jax.random.PRNGKey(seed)
        last_tok = np.zeros((self.max_batch,), np.int32)
        outs: List[List[int]] = [[] for _ in prompts]
        try:
            firsts = [self.insert_request(p, i,
                                          extra=extras[i] if extras else None)
                      for i, p in enumerate(prompts)]
        except Exception:
            # a failed insert (e.g. pool exhausted mid-batch) must not
            # strand the prompts already inserted: their slots would stay
            # active with their pages allocated forever
            for i in range(len(prompts)):
                self.release_slot(i)
            raise
        for i, f in enumerate(firsts):            # one deferred sync point
            first = int(f)
            outs[i].append(first)
            last_tok[i] = first
        t_first = _now() - t0                     # all prefills + first toks
        done = [False] * len(prompts)
        capped = [False] * len(prompts)
        for step in range(max_new_tokens - 1):
            # a slot at cache capacity cannot decode another token — stop
            # rather than collect the masked 0s step() emits for it (the
            # scheduler path retires the same condition as MAX_SEQ_EXCEEDED;
            # here the result reports finished=False)
            for i in range(len(prompts)):
                if not done[i] and self.capacity_left(i) <= 0:
                    done[i] = capped[i] = True
                    self.release_slot(i)
            if all(done):
                break
            rng, sub = jax.random.split(rng)
            nxt = self.step(last_tok, sub, temperature)
            for i in range(len(prompts)):
                if done[i]:
                    continue
                tok = int(nxt[i])
                outs[i].append(tok)
                last_tok[i] = tok
                if self.eos_id is not None and tok == self.eos_id:
                    # release NOW, not at the end of the batch: a done slot
                    # left active keeps decoding (wasted compute) and keeps
                    # advancing its cache length — drifting vs the
                    # scheduler path's chunk-boundary retire
                    done[i] = True
                    self.release_slot(i)
            if all(done):
                break
        dt = _now() - t0
        results = []
        for i, p in enumerate(prompts):
            finished = bool(done[i]) if self.eos_id is not None else True
            results.append(GenerationResult(
                tokens=outs[i], prompt_len=len(p), steps=len(outs[i]),
                finished=finished and not capped[i],   # capacity-truncated
                latency_s=dt, first_token_s=t_first))
            self.release_slot(i)
        return results
