"""Generation engine: compiled prefill + batched decode with slot management.

The engine owns a fixed-capacity decode batch (``max_batch`` slots, each
with a ``max_seq`` cache). Requests are prefetched one at a time (prompt
padded to a power-of-two bucket so the number of compiled prefill programs
stays small) and *inserted* into a free slot of the running batch cache —
the mechanism continuous batching (scheduler.py) is built on.

All hot functions are jitted once per (bucket) shape:
- ``_prefill_one``: prompt [1, bucket] -> (last logits, single-slot cache)
- ``_insert``: copy a single-slot cache into slot ``i`` of the batch cache
- ``_decode``: one step for all slots (+ sampling), inactive slots masked
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.sampling import mask_padded_vocab

F32 = jnp.float32


def _bucket(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


@dataclass
class GenerationResult:
    tokens: List[int]
    prompt_len: int
    steps: int
    finished: bool
    latency_s: float = 0.0


class GenerationEngine:
    """Single-host serving engine for one model asset."""

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_seq: int = 512, eos_id: Optional[int] = None,
                 extra_inputs: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        # static per-request extra inputs (e.g. image embeds builder)
        self.extra_inputs = extra_inputs or {}

        self._cache = model.init_cache(max_batch, max_seq)
        self._lengths = np.zeros((max_batch,), np.int32)
        self._active = np.zeros((max_batch,), bool)

        self._prefill_jit: Dict[int, Any] = {}
        self._decode = jax.jit(self._decode_impl)
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))

    # -- jitted internals ---------------------------------------------------

    def _prefill_impl(self, params, batch):
        return self.model.prefill(params, batch, cache_len=self.max_seq)

    def _insert_impl(self, batch_cache, one_cache, slot):
        """Copy a B=1 cache into slot ``slot`` of the batch cache.

        The batch axis of each leaf is located structurally: the first axis
        where the source is 1 and the destination is ``max_batch``. (Leading
        layer-stack dims match between src and dst, so they never trigger.)
        """
        def put(dst, src):
            if dst.ndim == 1:                       # lengths [B]
                return dst.at[slot].set(src[0])
            for ax in range(dst.ndim):
                if src.shape[ax] == 1 and dst.shape[ax] == self.max_batch:
                    idx = (slice(None),) * ax + (slot,)
                    return dst.at[idx].set(jnp.squeeze(src, ax))
            return dst
        return jax.tree.map(put, batch_cache, one_cache)

    def _decode_impl(self, params, cache, tokens, rng, temperature, active):
        """One decode step; ``temperature`` is a per-slot [max_batch]
        vector so mixed-temperature batches don't interfere — each row
        samples at its own temperature, rows at 0 take the greedy argmax.
        The fixed vector shape keeps the step compile-stable."""
        logits, cache = self.model.decode_step(params, cache, tokens)
        masked = mask_padded_vocab(logits, self.cfg.vocab_size)
        greedy = jnp.argmax(masked, axis=-1).astype(jnp.int32)
        scaled = masked / jnp.maximum(temperature, 1e-6)[:, None]
        sampled = jax.random.categorical(rng, scaled, axis=-1) \
            .astype(jnp.int32)
        nxt = jnp.where(temperature > 0, sampled, greedy)
        nxt = jnp.where(active, nxt, 0)
        return nxt, cache

    # -- public API ------------------------------------------------------------

    def fits_prompt(self, n: int) -> bool:
        """Whether an ``n``-token prompt fits a slot (its padding bucket must
        not exceed ``max_seq``) — lets callers reject before occupying the
        admission path."""
        return _bucket(n) <= self.max_seq

    def free_slots(self) -> List[int]:
        return [i for i in range(self.max_batch) if not self._active[i]]

    def insert_request(self, prompt: List[int], slot: int,
                       extra: Optional[Dict[str, Any]] = None) -> jnp.ndarray:
        """Prefill ``prompt`` and place it into ``slot``. Returns last logits."""
        assert not self._active[slot], f"slot {slot} busy"
        bucket = _bucket(len(prompt))
        if bucket > self.max_seq:
            raise ValueError(f"prompt {len(prompt)} exceeds max_seq {self.max_seq}")
        if bucket not in self._prefill_jit:
            self._prefill_jit[bucket] = jax.jit(self._prefill_impl)
        # Ring-cache families (sliding-window / hybrid local attention) need
        # contiguous positions, so their prompts are LEFT-padded and pads are
        # treated as context. Linear caches RIGHT-pad; causal masking keeps
        # pads out of real-token attention and decode masks by true length.
        # (SSM states are cumulative too, so stateful families all left-pad.)
        ring = (self.cfg.family in ("hybrid", "ssm")
                or self.cfg.sliding_window is not None)
        padded = np.zeros((1, bucket), np.int32)
        if ring:
            padded[0, bucket - len(prompt):] = prompt
            true_len = bucket
        else:
            padded[0, :len(prompt)] = prompt
            true_len = len(prompt)
        batch = {"tokens": jnp.asarray(padded),
                 "prompt_lengths": jnp.asarray([true_len], np.int32)}
        for k, v in (extra or self.extra_inputs).items():
            batch[k] = v
        logits, one_cache = self._prefill_jit[bucket](self.params, batch)
        self._cache = self._insert(self._cache, one_cache,
                                   jnp.asarray(slot, jnp.int32))
        self._lengths[slot] = true_len
        self._active[slot] = True
        return logits

    def release_slot(self, slot: int):
        self._active[slot] = False

    def step(self, tokens: np.ndarray, rng, temperature=0.0):
        """One decode step for the whole batch. tokens [max_batch] int32;
        ``temperature`` is a scalar (applied to every slot) or a per-slot
        [max_batch] vector."""
        active = jnp.asarray(self._active)
        temps = np.broadcast_to(np.asarray(temperature, np.float32),
                                (self.max_batch,))
        nxt, self._cache = self._decode(
            self.params, self._cache, jnp.asarray(tokens, jnp.int32), rng,
            jnp.asarray(temps, F32), active)
        self._lengths[self._active] += 1
        return np.asarray(nxt)

    # -- convenience: synchronous batch generation ------------------------------

    def generate(self, prompts: List[List[int]], *, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 extras: Optional[List[Dict[str, Any]]] = None,
                 ) -> List[GenerationResult]:
        """Generate for up to ``max_batch`` prompts at once (convenience path;
        the scheduler drives the slot API directly for continuous batching)."""
        assert len(prompts) <= self.max_batch
        t0 = time.perf_counter()
        rng = jax.random.PRNGKey(seed)
        last_tok = np.zeros((self.max_batch,), np.int32)
        outs: List[List[int]] = [[] for _ in prompts]
        for i, p in enumerate(prompts):
            logits = self.insert_request(
                p, i, extra=extras[i] if extras else None)
            first = int(np.asarray(jnp.argmax(
                jnp.where(jnp.arange(logits.shape[-1]) < self.cfg.vocab_size,
                          logits[0], -1e9))))
            outs[i].append(first)
            last_tok[i] = first
        done = [False] * len(prompts)
        for step in range(max_new_tokens - 1):
            rng, sub = jax.random.split(rng)
            nxt = self.step(last_tok, sub, temperature)
            for i in range(len(prompts)):
                if done[i]:
                    continue
                tok = int(nxt[i])
                outs[i].append(tok)
                last_tok[i] = tok
                if self.eos_id is not None and tok == self.eos_id:
                    done[i] = True
            if all(done):
                break
        dt = time.perf_counter() - t0
        results = []
        for i, p in enumerate(prompts):
            results.append(GenerationResult(
                tokens=outs[i], prompt_len=len(p), steps=len(outs[i]),
                finished=bool(done[i]) if self.eos_id is not None else True,
                latency_s=dt))
            self.release_slot(i)
        return results
