from repro.serving.engine import GenerationEngine, GenerationResult
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import ContinuousBatchingScheduler, Request, SchedulerStats
from repro.serving.sampling import sample, mask_padded_vocab
from repro.serving.metrics import Counter, Histogram, MetricsRegistry
from repro.serving.tracing import RequestTrace, Tracer
from repro.serving.qos import (
    AdmissionController, AdmissionError, DeadlineExceeded, InvalidPriority,
    QoSConfig, QueueFull, RateLimited, PRIORITIES,
)
