from repro.serving.engine import GenerationEngine, GenerationResult
from repro.serving.scheduler import ContinuousBatchingScheduler, Request, SchedulerStats
from repro.serving.sampling import sample, mask_padded_vocab
