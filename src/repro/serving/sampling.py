"""Token sampling from logits: greedy / temperature / top-k."""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def mask_padded_vocab(logits, logical_vocab: int):
    V = logits.shape[-1]
    if V == logical_vocab:
        return logits
    col = jnp.arange(V) < logical_vocab
    return jnp.where(col, logits, -1e9)


def sample(logits, rng, *, temperature: float = 0.0, top_k: int = 0,
           logical_vocab: int | None = None):
    """logits [B, V] -> tokens [B]. temperature==0 -> greedy."""
    if logical_vocab is not None:
        logits = mask_padded_vocab(logits, logical_vocab)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e9, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
