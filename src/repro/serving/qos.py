"""QoS admission control for the serving path.

``BatchedService``'s original admission was a bare bounded FIFO deque with
``QUEUE_FULL`` as the only backpressure: under sustained overload one
greedy client fills the queue and starves everyone — the canonical
production-deployment failure mode for a model exchange that promises to
serve arbitrary developer traffic through one REST surface.

This module replaces the deque with an :class:`AdmissionController`:

- **priority classes** ``interactive > batch > best_effort`` scheduled by
  *smooth weighted round-robin* — higher classes get proportionally more
  dequeues (default 8:3:1) but no non-empty class is ever starved (the
  per-class no-starvation property test rides on this);
- **per-client fairness** inside each class via deficit round-robin over
  client identities (``X-MAX-Client`` header / job metadata, default
  ``anon``) — a greedy client queues behind its own backlog, not everyone
  else's;
- **per-client token-bucket rate limits** (requests/s with burst) that
  reject at submit time with ``RATE_LIMITED``;
- **deadline-aware load shedding**: work whose client-supplied deadline
  expired while queued is failed with ``DEADLINE_EXCEEDED`` at the next
  dequeue sweep instead of rotting in queue and occupying decode slots;
- **bounded per-class queues** so a flood in one class cannot block
  admission of another (``QUEUE_FULL`` stays per-class backpressure).

The controller never touches engine state; it only decides *order*. The
scheduler asks it for the next ``k`` admissions, the services translate
its structured :class:`AdmissionError` codes into error envelopes, and
every decision is recorded in a :class:`~repro.serving.metrics.MetricsRegistry`.
"""

from __future__ import annotations

import itertools
import math
import threading
from collections import deque
from dataclasses import dataclass, field, replace as _dc_replace
from types import MappingProxyType
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.serving.metrics import MetricsRegistry
from repro.serving.tracing import now as tracing_now

#: priority classes, highest first — order is the tiebreak in WRR
PRIORITIES: Tuple[str, ...] = ("interactive", "batch", "best_effort")

DEFAULT_CLASS_WEIGHTS: Mapping[str, int] = MappingProxyType({
    "interactive": 8, "batch": 3, "best_effort": 1,
})

DEFAULT_CLIENT = "anon"


class AdmissionError(Exception):
    """Structured admission failure; ``code`` maps to the HTTP surface.

    Deliberately NOT a :class:`~repro.core.wrapper.MAXError` subclass —
    qos must stay importable without the core package (no cycle through
    ``core.service``); the service/API layers translate explicitly.

    ``retry_after_s`` is an optional client back-off hint; the HTTP layer
    surfaces it as a ``Retry-After`` header on 429/503 responses."""
    code = "INTERNAL"

    def __init__(self, *args, retry_after_s: Optional[float] = None):
        super().__init__(*args)
        self.retry_after_s = retry_after_s


class InvalidPriority(AdmissionError):
    """Unknown priority class on the request (HTTP 400)."""
    code = "INVALID_INPUT"


class RateLimited(AdmissionError):
    """Per-client token bucket empty — back off (HTTP 429)."""
    code = "RATE_LIMITED"


class QueueFull(AdmissionError):
    """The priority class's queue is at capacity (HTTP 429)."""
    code = "QUEUE_FULL"


class DeadlineExceeded(AdmissionError):
    """Client-supplied deadline passed before the work could run (504)."""
    code = "DEADLINE_EXCEEDED"


class Degraded(AdmissionError):
    """SOFT brownout: best_effort work is shed at admission (HTTP 503)."""
    code = "DEGRADED"


class CircuitOpen(AdmissionError):
    """HARD brownout: circuit breaker is open, nothing admits (HTTP 503)."""
    code = "CIRCUIT_OPEN"


@dataclass(frozen=True)
class QoSConfig:
    """Admission policy for one deployment. JSON-friendly via
    :meth:`from_json` so it can ride the v2 deploy body."""

    max_queue: int = 64                 # per priority class
    class_weights: Mapping[str, int] = field(
        default_factory=lambda: dict(DEFAULT_CLASS_WEIGHTS))
    rate: Optional[float] = None        # cost units/s per client; None = off
    burst: Optional[float] = None       # bucket size; default max(rate, 1)
    default_priority: str = "batch"
    quantum: float = 1.0                # DRR quantum (cost units per visit)
    policy: str = "drr"                 # "drr" | "fifo" (fifo = legacy order)
    # what one cost unit means: "request" charges a flat 1 per request,
    # "token" charges max_new_tokens — long generations drain the bucket
    # (and earn DRR deficit) proportionally to the decode work they buy,
    # so they are priced honestly instead of riding a flat tariff
    rate_unit: str = "request"          # "request" | "token"

    def __post_init__(self):
        if self.policy not in ("drr", "fifo"):
            raise ValueError(f"unknown qos policy {self.policy!r}")
        if self.rate_unit not in ("request", "token"):
            raise ValueError(f"unknown rate_unit {self.rate_unit!r} "
                             "(expected 'request' or 'token')")
        if self.default_priority not in self.class_weights:
            raise ValueError(
                f"default_priority {self.default_priority!r} not in "
                f"class_weights {sorted(self.class_weights)}")
        if any(w <= 0 for w in self.class_weights.values()):
            raise ValueError("class weights must be positive")
        if self.max_queue <= 0:
            raise ValueError("max_queue must be positive")
        if self.quantum <= 0:
            # a zero quantum would never earn any client enough deficit to
            # dequeue — the DRR loop would spin forever
            raise ValueError("quantum must be positive")
        if self.rate is not None and self.rate <= 0:
            raise ValueError("rate must be positive (or null to disable)")

    #: token budget charged for a generation request that omits
    #: max_new_tokens — matches the generation wrappers' default
    #: (core/assets.py), or a client could dodge token pricing by
    #: leaving the field out
    DEFAULT_TOKEN_BUDGET = 16

    def request_cost(self, max_new_tokens: Optional[int] = None) -> float:
        """Admission cost of one request under this config: a flat 1, or
        its token budget when ``rate_unit == "token"``. The single source
        of truth for both service kinds and the scheduler — they must not
        price the same request differently."""
        if self.rate_unit != "token":
            return 1.0
        n = self.DEFAULT_TOKEN_BUDGET if max_new_tokens is None \
            else max_new_tokens
        return float(max(1, int(n)))

    @property
    def classes(self) -> List[str]:
        """Classes in service-priority order (known first, then extras)."""
        known = [c for c in PRIORITIES if c in self.class_weights]
        extra = sorted(c for c in self.class_weights if c not in PRIORITIES)
        return known + extra

    def for_replica(self) -> "QoSConfig":
        """Per-replica copy with client rate limiting stripped: the fleet
        front door charges each client's token bucket once, globally;
        replicas keep the queue bounds and DRR ordering only. Without the
        strip, a dispatched request would be charged twice and every
        client's effective rate would halve."""
        if self.rate is None:
            return self
        return _dc_replace(self, rate=None, burst=None)

    @classmethod
    def from_json(cls, d: Optional[Mapping[str, Any]]) -> "QoSConfig":
        if d is None:
            return cls()
        if isinstance(d, QoSConfig):
            return d
        if not isinstance(d, Mapping):
            raise ValueError("qos config must be a JSON object")
        allowed = {"max_queue", "class_weights", "rate", "burst",
                   "default_priority", "quantum", "policy", "rate_unit"}
        unknown = set(d) - allowed
        if unknown:
            raise ValueError(f"unknown qos config keys {sorted(unknown)} "
                             f"(expected subset of {sorted(allowed)})")
        return cls(**dict(d))


@dataclass
class Ticket:
    """One queued unit of work plus its admission metadata."""
    item: Any
    priority: str
    client: str
    cost: float
    seq: int
    enqueued_at: float                    # monotonic
    deadline: Optional[float] = None      # monotonic absolute, or None


class _TokenBucket:
    __slots__ = ("rate", "burst", "tokens", "last")

    def __init__(self, rate: float, burst: float, now: float):
        self.rate = rate
        self.burst = burst
        self.tokens = burst
        self.last = now

    def try_take(self, cost: float, now: float) -> bool:
        self.tokens = min(self.burst,
                          self.tokens + (now - self.last) * self.rate)
        self.last = now
        if self.tokens >= cost:
            self.tokens -= cost
            return True
        return False


class AdmissionController:
    """Priority + fairness + rate-limit + deadline admission.

    Thread-safe: ``submit`` runs on request threads while ``take`` runs on
    the scheduler's worker thread. ``clock`` is injectable (monotonic
    seconds) so token-bucket refill and deadline shedding are deterministic
    under test.
    """

    def __init__(self, config: Optional[QoSConfig] = None, *,
                 metrics: Optional[MetricsRegistry] = None,
                 model_id: str = "", clock=tracing_now):
        self.cfg = config or QoSConfig()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.metrics.describe(
            "max_requests_total",
            "Finished/rejected requests by model, outcome and priority "
            "class (rejections counted at submit time)")
        self.metrics.describe(
            "max_queue_wait_seconds",
            "Admission-queue wait per admitted request, by priority class")
        self.metrics.describe(
            "max_shed_total",
            "Requests shed by deadline while queued, by priority class")
        self.model_id = model_id
        self._clock = clock
        self._lock = threading.Lock()
        self._seq = itertools.count()
        # class -> client -> FIFO of tickets
        self._queues: Dict[str, Dict[str, deque]] = {
            c: {} for c in self.cfg.classes}
        self._rotation: Dict[str, deque] = {
            c: deque() for c in self.cfg.classes}   # DRR client order
        self._deficit: Dict[Tuple[str, str], float] = {}
        self._wrr_credit: Dict[str, float] = {c: 0.0 for c in self.cfg.classes}
        self._buckets: Dict[str, _TokenBucket] = {}
        self._depth_by_class: Dict[str, int] = {c: 0 for c in self.cfg.classes}
        self.shed_total = 0
        self.rate_limited_total = 0
        self.queue_full_total = 0

    # -- submit path (request threads) ------------------------------------

    def _labels(self, priority: str) -> Dict[str, str]:
        return {"model": self.model_id, "class": priority}

    def try_acquire(self, client: str, cost: float = 1.0,
                    priority: Optional[str] = None) -> None:
        """Token-bucket check only (no queuing) — the sync service's
        admission. Raises :class:`InvalidPriority` / :class:`RateLimited`."""
        priority = priority or self.cfg.default_priority
        if priority not in self._queues:
            raise InvalidPriority(
                f"unknown priority class {priority!r} "
                f"(expected one of {self.cfg.classes})")
        if self.cfg.rate is None:
            return
        now = self._clock()
        with self._lock:
            bucket = self._buckets.get(client)
            if bucket is None:
                burst = self.cfg.burst if self.cfg.burst is not None \
                    else max(self.cfg.rate, 1.0)
                bucket = self._buckets[client] = _TokenBucket(
                    self.cfg.rate, burst, now)
            ok = bucket.try_take(cost, now)
        if not ok:
            with self._lock:
                self.rate_limited_total += 1
            self.metrics.inc("max_requests_total", 1,
                             outcome="rate_limited", **self._labels(priority))
            unit = "token" if self.cfg.rate_unit == "token" else "req"
            raise RateLimited(
                f"client {client!r} exceeded {self.cfg.rate:g} {unit}/s "
                f"(burst {bucket.burst:g}); retry later")

    def submit(self, item: Any, *, priority: Optional[str] = None,
               client: Optional[str] = None, cost: float = 1.0,
               deadline_s: Optional[float] = None) -> Ticket:
        """Admit ``item`` into the queue or raise an :class:`AdmissionError`.

        ``deadline_s`` is relative (seconds from now); expiry is enforced
        at dequeue sweeps, so a doomed item is failed, never executed.
        """
        priority = priority or self.cfg.default_priority
        client = client or DEFAULT_CLIENT
        if priority not in self._queues:
            raise InvalidPriority(
                f"unknown priority class {priority!r} "
                f"(expected one of {self.cfg.classes})")
        self.try_acquire(client, cost, priority)
        now = self._clock()
        ticket = Ticket(item=item, priority=priority, client=client,
                        cost=cost, seq=next(self._seq), enqueued_at=now,
                        deadline=None if deadline_s is None
                        else now + deadline_s)
        with self._lock:
            if self._depth_by_class[priority] >= self.cfg.max_queue:
                self.queue_full_total += 1
                full = True
            else:
                full = False
                q = self._queues[priority].get(client)
                if q is None:
                    q = self._queues[priority][client] = deque()
                if not q:
                    self._rotation[priority].append(client)
                q.append(ticket)
                self._depth_by_class[priority] += 1
        if full:
            self.metrics.inc("max_requests_total", 1, outcome="queue_full",
                             **self._labels(priority))
            raise QueueFull(
                f"{priority!r} queue full ({self.cfg.max_queue}); "
                "retry later")
        return ticket

    # -- dequeue path (scheduler worker) ----------------------------------

    def _sweep_expired(self, now: float) -> List[Ticket]:
        """Drop every expired or cancelled ticket (lock held). Cancellation
        is duck-typed off ``item.cancelled`` so this module stays importable
        without the scheduler — only the deadline-expired tickets count
        toward ``shed_total`` (the caller tells them apart the same way)."""

        def dead(t: Ticket) -> bool:
            return (getattr(t.item, "cancelled", False)
                    or (t.deadline is not None and t.deadline <= now))

        shed: List[Ticket] = []
        for cls, by_client in self._queues.items():
            for client in list(by_client):
                q = by_client[client]
                kept = deque(t for t in q if not dead(t))
                if len(kept) != len(q):
                    shed.extend(t for t in q if dead(t))
                    self._depth_by_class[cls] -= len(q) - len(kept)
                    by_client[client] = kept
                    if not kept:
                        del by_client[client]
                        try:
                            self._rotation[cls].remove(client)
                        except ValueError:
                            pass
                        self._deficit.pop((cls, client), None)
        self.shed_total += sum(
            1 for t in shed if not getattr(t.item, "cancelled", False))
        return shed

    def _pick_class(self) -> str:
        """Smooth weighted round-robin over non-empty classes: service
        proportional to weight, highest class on ties — never starves a
        non-empty class."""
        nonempty = [c for c in self.cfg.classes if self._depth_by_class[c]]
        if len(nonempty) == 1:
            return nonempty[0]
        if self.cfg.policy == "fifo":
            # legacy global arrival order: class of the oldest ticket
            return min(nonempty,
                       key=lambda c: min(q[0].seq
                                         for q in self._queues[c].values()))
        total = sum(self.cfg.class_weights[c] for c in nonempty)
        for c in nonempty:
            self._wrr_credit[c] += self.cfg.class_weights[c]
        order = {c: i for i, c in enumerate(self.cfg.classes)}
        best = max(nonempty,
                   key=lambda c: (self._wrr_credit[c], -order[c]))
        self._wrr_credit[best] -= total
        return best

    def _pop_from_class(self, cls: str) -> Ticket:
        """Deficit round-robin across this class's clients (lock held)."""
        rot, by_client = self._rotation[cls], self._queues[cls]
        if self.cfg.policy == "fifo":
            client = min(by_client, key=lambda c: by_client[c][0].seq)
        else:
            # arithmetic fast-forward: with token-unit costs a head request
            # may need thousands of quanta — credit the whole rounds every
            # client would accrue in one pass instead of spinning
            # O(cost/quantum) visits under the admission lock. Identical to
            # running the visit loop that many full rotations.
            rounds = min(
                max(1.0, math.ceil(
                    (by_client[c][0].cost
                     - self._deficit.get((cls, c), 0.0)) / self.cfg.quantum))
                for c in rot)
            if rounds > 1:
                for c in rot:
                    key = (cls, c)
                    self._deficit[key] = self._deficit.get(key, 0.0) \
                        + (rounds - 1) * self.cfg.quantum
            while True:                 # terminates within one rotation now
                client = rot[0]
                key = (cls, client)
                self._deficit[key] = self._deficit.get(key, 0.0) \
                    + self.cfg.quantum
                if self._deficit[key] >= by_client[client][0].cost:
                    break
                rot.rotate(-1)          # not enough credit: next client
        q = by_client[client]
        ticket = q.popleft()
        self._depth_by_class[cls] -= 1
        if self.cfg.policy != "fifo":
            self._deficit[(cls, client)] -= ticket.cost
        if not q:
            del by_client[client]
            try:
                rot.remove(client)
            except ValueError:
                pass
            self._deficit.pop((cls, client), None)
        elif self.cfg.policy != "fifo":
            rot.rotate(-1)              # one pop per visit: move on
        return ticket

    def take(self, k: int) -> Tuple[List[Ticket], List[Ticket]]:
        """Dequeue up to ``k`` tickets in QoS order.

        Returns ``(admitted, shed)`` — ``shed`` are deadline-expired
        tickets the caller must fail with ``DEADLINE_EXCEEDED``, plus
        cancelled ones (``item.cancelled``) it must retire as
        ``CANCELLED``. Expired/cancelled work is swept even when ``k == 0``
        so a full decode batch cannot make doomed work rot in queue.
        """
        now = self._clock()
        admitted: List[Ticket] = []
        with self._lock:
            shed = self._sweep_expired(now)
            while len(admitted) < k and self.depth_locked() > 0:
                admitted.append(self._pop_from_class(self._pick_class()))
        for t in admitted:
            self.metrics.observe("max_queue_wait_seconds",
                                 max(0.0, now - t.enqueued_at),
                                 **self._labels(t.priority))
        for t in shed:
            if not getattr(t.item, "cancelled", False):
                self.metrics.inc("max_shed_total", 1,
                                 **self._labels(t.priority))
        return admitted, shed

    # -- introspection -----------------------------------------------------

    def depth_locked(self) -> int:
        return sum(self._depth_by_class.values())

    def depth(self) -> int:
        with self._lock:
            return self.depth_locked()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            by_class = {c: n for c, n in self._depth_by_class.items()}
            by_client: Dict[str, int] = {}
            for by_c in self._queues.values():
                for client, q in by_c.items():
                    by_client[client] = by_client.get(client, 0) + len(q)
            return {
                "policy": self.cfg.policy,
                "queued": sum(by_class.values()),
                "queued_by_class": by_class,
                "queued_by_client": by_client,
                "shed": self.shed_total,
                "rate_limited": self.rate_limited_total,
                "queue_full": self.queue_full_total,
                "rate": self.cfg.rate,
                "rate_unit": self.cfg.rate_unit,
                "max_queue_per_class": self.cfg.max_queue,
            }
