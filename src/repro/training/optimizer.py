"""AdamW optimizer (pure pytree implementation; no optax dependency).

Moments can be kept in a reduced dtype for >70B-parameter configs (the
dry-run memory budget on a 256-chip v5e pod) — precision tradeoff recorded
in DESIGN.md.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


class AdamW(NamedTuple):
    init: Callable
    update: Callable


def adamw(lr_fn, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, moment_dtype=jnp.float32,
          grad_clip_norm: float = 1.0) -> AdamW:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        # global grad-norm clip
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
        lr = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(F32)
        bc2 = 1 - b2 ** step.astype(F32)

        def upd(g, m, v, p):
            g = g.astype(F32) * scale
            m_new = b1 * m.astype(F32) + (1 - b1) * g
            v_new = b2 * v.astype(F32) + (1 - b2) * jnp.square(g)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(F32)
            p_new = p.astype(F32) - lr * delta
            return (p_new.astype(p.dtype), m_new.astype(moment_dtype),
                    v_new.astype(moment_dtype))

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        params_new = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        m_new = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        v_new = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return params_new, AdamWState(step, m_new, v_new), {
            "grad_norm": gnorm, "lr": lr}

    return AdamW(init, update)
