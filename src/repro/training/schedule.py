"""LR schedules: linear-warmup cosine, and WSD (warmup-stable-decay).

WSD is MiniCPM's schedule (arXiv:2404.06395): warmup -> long stable plateau
-> short (10%) exponential-ish decay. Implemented as pure functions of the
step (safe inside jit).
"""

from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int,
                  total_steps: int, final_frac: float = 0.1):
    step = jnp.asarray(step, F32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    progress = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    progress = jnp.clip(progress, 0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac)
                     * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
    return jnp.where(step < warmup_steps, warm, cos)


def wsd(step, *, peak_lr: float, warmup_steps: int, total_steps: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup -> stable plateau -> short decay (last ``decay_frac``)."""
    step = jnp.asarray(step, F32)
    decay_steps = jnp.maximum(total_steps * decay_frac, 1.0)
    decay_start = total_steps - decay_steps
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    # exponential decay from peak to final_frac*peak across the decay window
    t = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
    dec = peak_lr * jnp.exp(jnp.log(final_frac) * t)
    lr = jnp.where(step < warmup_steps, warm,
                   jnp.where(step < decay_start, peak_lr, dec))
    return lr


def make_schedule(name: str, *, peak_lr: float, warmup_steps: int,
                  total_steps: int):
    if name == "cosine":
        return lambda s: warmup_cosine(s, peak_lr=peak_lr,
                                       warmup_steps=warmup_steps,
                                       total_steps=total_steps)
    if name == "wsd":
        return lambda s: wsd(s, peak_lr=peak_lr, warmup_steps=warmup_steps,
                             total_steps=total_steps)
    raise ValueError(f"unknown schedule {name!r}")
