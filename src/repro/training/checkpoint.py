"""Checkpointing: pytree <-> .npz with path-flattened keys + JSON manifest.

Host-gathered (fine for CPU tests and the demo assets; a pod deployment
would stream shards — the layout here keeps one array per flattened path
so a sharded writer is a drop-in change).
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat: Dict[str, np.ndarray] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for k in path:
            if isinstance(k, jax.tree_util.DictKey):
                keys.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                keys.append(str(k.idx))
            else:
                keys.append(str(k))
        flat[_SEP.join(keys)] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree, *, step: Optional[int] = None,
                    extra: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    manifest = {
        "step": step,
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    mpath = (path[:-4] if path.endswith(".npz") else path) + ".json"
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)


def restore_checkpoint(path: str, like) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    mpath = (path[:-4] if path.endswith(".npz") else path) + ".json"
    manifest = {}
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)

    flat_like = _flatten_paths(like)
    leaves = []
    for key, ref in flat_like:
        if key not in npz:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = npz[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {ref.shape}")
        leaves.append(arr.astype(ref.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, manifest


def _flatten_paths(tree):
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for k in path:
            if isinstance(k, jax.tree_util.DictKey):
                keys.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                keys.append(str(k.idx))
            else:
                keys.append(str(k))
        out.append((_SEP.join(keys), leaf))
    return out
