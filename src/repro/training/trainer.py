"""Train step assembly: grad accumulation (microbatching), metrics, state.

``make_train_step(model, opt, num_microbatches)`` returns a pure
``train_step(state, batch) -> (state, metrics)`` suitable for ``jax.jit``
or pjit. Microbatching reshapes the global batch to
``[num_micro, micro, ...]`` and accumulates grads with ``lax.scan`` —
the standard memory lever for the 100B+ configs on the dry-run mesh
(activations live only per-microbatch; remat inside the model bounds them
further).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import AdamW, AdamWState

F32 = jnp.float32


class TrainState(NamedTuple):
    params: Any
    opt_state: AdamWState


def init_train_state(model: Model, opt: AdamW, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params, opt.init(params))


def make_train_step(model: Model, opt: AdamW, *, num_microbatches: int = 1,
                    accum_dtype=F32):
    """``accum_dtype=bf16`` halves gradient-accumulation memory and (when
    the backend lowers grad reductions as full all-reduces) collective
    bytes — used for the >=60B configs (§Perf H2 iter 4)."""
    loss_fn = model.loss

    def grads_for(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if accum_dtype != F32:
            # cast at the source so the convert can sink below the gradient
            # cross-shard reduction (halves its wire bytes)
            grads = jax.tree.map(lambda g: g.astype(accum_dtype), grads)
        return grads, metrics

    def train_step(state: TrainState, batch):
        if num_microbatches == 1:
            grads, metrics = grads_for(state.params, batch)
        else:
            def split(x):
                n = num_microbatches
                assert x.shape[0] % n == 0, (
                    f"global batch {x.shape[0]} not divisible by {n} microbatches")
                return x.reshape((n, x.shape[0] // n) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            from repro.sharding.specs import shard_like_params

            def body(carry, mb):
                acc, _ = carry
                g, m = grads_for(state.params, mb)
                acc = shard_like_params(jax.tree.map(
                    lambda a, gi: a + gi.astype(accum_dtype), acc, g))
                return (acc, m), None

            zeros = shard_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                             state.params))
            dummy_metrics = {
                "ce": jnp.zeros((), F32), "loss": jnp.zeros((), F32)}
            if model.cfg.is_moe:
                dummy_metrics.update(moe_lb=jnp.zeros((), F32),
                                     moe_z=jnp.zeros((), F32))
            (grads, metrics), _ = jax.lax.scan(
                body, (zeros, dummy_metrics), micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)

        params, opt_state, opt_metrics = opt.update(
            grads, state.opt_state, state.params)
        metrics = dict(metrics, **opt_metrics)
        return TrainState(params, opt_state), metrics

    return train_step
