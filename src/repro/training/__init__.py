from repro.training.optimizer import AdamW, AdamWState, adamw
from repro.training.schedule import make_schedule, warmup_cosine, wsd
from repro.training.trainer import TrainState, init_train_state, make_train_step
from repro.training.data import DataConfig, batches
from repro.training.checkpoint import save_checkpoint, restore_checkpoint
