"""Data pipeline: token sources, sequence packing, batching.

Offline container -> the default source is a seeded synthetic corpus
(Zipfian token stream with local n-gram structure so a model can actually
reduce loss on it); a file-backed source reads raw bytes through the byte
tokenizer. Documents are packed into fixed-length rows with EOS separators
(loss masked on pads), the standard LM pipeline shape.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.data.tokenizer import TOKENIZER


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 128
    global_batch: int = 8
    vocab_size: int = 512
    seed: int = 0
    source: str = "synthetic"     # synthetic | bytes:<path>


class SyntheticCorpus:
    """Zipfian unigrams + order-2 structure: token ~ f(prev) half the time.

    The deterministic structure means cross-entropy has real headroom below
    the unigram entropy — training tests assert the loss drops.
    """

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.unigram = (1 / ranks) / np.sum(1 / ranks)
        # fixed "grammar": each token has a preferred successor
        self.successor = self.rng.permutation(vocab_size)

    def documents(self, *, mean_len: int = 64) -> Iterator[List[int]]:
        while True:
            n = max(4, int(self.rng.exponential(mean_len)))
            doc = [int(self.rng.choice(self.vocab, p=self.unigram))]
            for _ in range(n - 1):
                if self.rng.random() < 0.5:
                    doc.append(int(self.successor[doc[-1]]))
                else:
                    doc.append(int(self.rng.choice(self.vocab, p=self.unigram)))
            yield doc


class ByteFileCorpus:
    def __init__(self, path: str, vocab_size: int):
        self.path = path
        self.vocab = vocab_size

    def documents(self) -> Iterator[List[int]]:
        with open(self.path, "rb") as f:
            data = f.read()
        chunk = 512
        while True:
            for i in range(0, max(len(data) - chunk, 1), chunk):
                yield [b % self.vocab for b in data[i:i + chunk]]


def pack_documents(docs: Iterator[List[int]], seq_len: int,
                   eos_id: int = TOKENIZER.eos_id) -> Iterator[np.ndarray]:
    """Greedy packing into rows of seq_len+1 (inputs+targets overlap)."""
    buf: List[int] = []
    for doc in docs:
        buf.extend(doc)
        buf.append(eos_id)
        while len(buf) >= seq_len + 1:
            yield np.asarray(buf[: seq_len + 1], np.int32)
            buf = buf[seq_len + 1:]


def batches(cfg: DataConfig) -> Iterator[dict]:
    if cfg.source == "synthetic":
        corpus = SyntheticCorpus(cfg.vocab_size, cfg.seed)
        docs = corpus.documents()
    elif cfg.source.startswith("bytes:"):
        docs = ByteFileCorpus(cfg.source[6:], cfg.vocab_size).documents()
    else:
        raise ValueError(f"unknown source {cfg.source!r}")
    rows = pack_documents(docs, cfg.seq_len)
    while True:
        stack = np.stack([next(rows) for _ in range(cfg.global_batch)])
        yield {
            "tokens": stack[:, :-1],
            "targets": stack[:, 1:],
            "loss_mask": (stack[:, 1:] != TOKENIZER.pad_id).astype(np.float32),
        }
