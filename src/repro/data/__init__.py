from repro.data.tokenizer import TOKENIZER, ByteTokenizer
