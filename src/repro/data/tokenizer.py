"""Byte-level tokenizer (vocab 512: 256 bytes + specials + headroom).

Deterministic and dependency-free so the demo assets (max-sentiment,
max-caption) and HTTP examples run offline. IDs 0..255 are raw bytes;
specials start at 256.
"""

from __future__ import annotations

from typing import List

PAD_ID = 0          # NUL byte doubles as pad
BOS_ID = 256
EOS_ID = 257
SEP_ID = 258
VOCAB_SIZE = 512


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    pad_id = PAD_ID
    bos_id = BOS_ID
    eos_id = EOS_ID
    sep_id = SEP_ID

    def encode(self, text: str, *, bos: bool = True, eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids: List[int]) -> str:
        data = bytes(i for i in ids if 0 < i < 256)
        return data.decode("utf-8", errors="replace")


TOKENIZER = ByteTokenizer()
