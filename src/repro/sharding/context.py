"""Logical-axis sharding annotations.

Model code names tensor dims with *logical* axes (``"batch"``, ``"heads"``,
``"ff"``, ...). A :class:`LogicalRules` context maps logical axes to mesh
axes and applies ``with_sharding_constraint`` — with a divisibility check,
so e.g. MiniCPM's 36 heads silently fall back to replicated on a 16-way
``model`` axis instead of erroring (recorded via ``rules.fallbacks``).

Outside any context (unit tests, CPU smoke runs) ``annotate`` is a no-op,
so model code is runnable on one device unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: dict[str, Union[str, Tuple[str, ...], None]] = {
    "batch": ("pod", "data"),      # pod composes with data when present
    "act_seq": "model",            # sequence-parallel residual stream
    "kv_seq": "model",             # decode-time context-parallel KV cache
    "heads": "model",
    "kv_heads": None,              # replicated (GQA groups < 16 in general)
    "ff": "model",
    "experts": "model",
    "vocab": "model",
    "embed": None,                 # d_model replicated in activations
    "fsdp": "data",                # parameter FSDP dim
    "lru": "model",
    "stack": None,                 # layer-stack dim of scanned params
    "capacity": None,
    "conv": None,
    "head_dim": None,
    "enc_seq": None,
}


class LogicalRules:
    def __init__(self, mesh: Mesh, overrides: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if overrides:
            self.rules.update(overrides)
        if "pod" not in mesh.axis_names:
            # single-pod mesh: drop the pod component from composite rules
            for k, v in list(self.rules.items()):
                if isinstance(v, tuple):
                    kept = tuple(a for a in v if a in mesh.axis_names)
                    self.rules[k] = kept if kept else None
        self.fallbacks: list[str] = []

    def _axis_size(self, mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        size = 1
        for a in mesh_axes:
            size *= self.mesh.shape[a]
        return size

    def spec(self, logical_axes: Sequence[Optional[str]],
             dim_sizes: Optional[Sequence[int]] = None) -> P:
        parts = []
        for i, name in enumerate(logical_axes):
            if name is None:
                parts.append(None)
                continue
            mesh_axes = self.rules.get(name)
            if mesh_axes is None:
                parts.append(None)
                continue
            if dim_sizes is not None:
                size = self._axis_size(mesh_axes)
                if dim_sizes[i] % size != 0:
                    self.fallbacks.append(
                        f"{name}:{dim_sizes[i]}%{size}!=0 -> replicated")
                    parts.append(None)
                    continue
            parts.append(mesh_axes)
        return P(*parts)

    def sharding(self, logical_axes, dim_sizes=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, dim_sizes))


_STATE = threading.local()


def current_rules() -> Optional[LogicalRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[LogicalRules]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def annotate(x, *logical_axes):
    """Attach a sharding constraint if a rules context is active."""
    rules = current_rules()
    if rules is None:
        return x
    assert len(logical_axes) == x.ndim, (
        f"annotate: {len(logical_axes)} axes for rank-{x.ndim} tensor")
    spec = rules.spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
