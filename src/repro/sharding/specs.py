"""Parameter / batch / cache PartitionSpec derivation.

Parameters are matched by leaf name (with parent-path disambiguation where
names collide, e.g. RWKV time-mix vs channel-mix ``w_k``). Base logical
axes describe the *unstacked* leaf; extra leading dims from layer stacking
get ``None`` prepended automatically. Divisibility fallbacks (e.g. 36 heads
on a 16-way axis) are handled by :class:`LogicalRules`.

Sharding scheme (single pod 16x16 ``(data, model)``; multi-pod prepends a
``pod`` axis that composes with ``data`` on the batch/fsdp dims):

- ``data``  = FSDP axis: batch AND one weight dim per matmul.
- ``model`` = tensor axis: heads / ff / experts / vocab / lru width, plus
  sequence-parallel residual activations and decode-time KV-cache sequence
  (context-parallel decode).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.context import LogicalRules

# (parent_hint, leaf_name) -> base logical axes. parent_hint None = any.
# Checked most-specific first.
_PARAM_RULES: list[tuple[Optional[str], str, Tuple[Optional[str], ...]]] = [
    # embeddings / head
    (None, "embed", ("vocab", "fsdp")),
    (None, "lm_head", ("fsdp", "vocab")),
    (None, "vision_proj", ("fsdp", None)),
    # attention
    (None, "wq", ("fsdp", "heads", None)),
    (None, "wk", ("fsdp", "kv_heads", None)),
    (None, "wv", ("fsdp", "kv_heads", None)),
    (None, "wo", ("heads", None, "fsdp")),
    # MoE (rank-3) before dense MLP (rank-2) — disambiguated by rank below
    ("moe", "w_gate", ("experts", "fsdp", None)),
    ("moe", "w_up", ("experts", "fsdp", None)),
    ("moe", "w_down", ("experts", None, "fsdp")),
    ("moe", "w_router", ("fsdp", None)),
    # dense MLP
    (None, "w_gate", ("fsdp", "ff")),
    (None, "w_up", ("fsdp", "ff")),
    (None, "w_down", ("ff", "fsdp")),
    # RG-LRU block
    (None, "w_in_rnn", ("fsdp", "lru")),
    (None, "w_in_gate", ("fsdp", "lru")),
    ("rec", "w_out", ("lru", "fsdp")),
    (None, "conv_w", (None, "lru")),
    (None, "gate_a_w", (None, None, None)),
    (None, "gate_x_w", (None, None, None)),
    # RWKV time-mix: output dim = H*N -> shard over model ("ff" rule reused)
    ("tm", "w_r", ("fsdp", "ff")),
    ("tm", "w_k", ("fsdp", "ff")),
    ("tm", "w_v", ("fsdp", "ff")),
    ("tm", "w_g", ("fsdp", "ff")),
    ("tm", "w_o", ("ff", "fsdp")),
    # RWKV channel-mix
    ("cm", "w_k", ("fsdp", "ff")),
    ("cm", "w_v", ("ff", "fsdp")),
    ("cm", "w_r", ("fsdp", None)),
]


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            out.append(k.name)
    return out


def _base_axes(path_names: list[str], shape) -> Tuple[Optional[str], ...]:
    leaf = path_names[-1]
    parents = path_names[:-1]
    for hint, name, axes in _PARAM_RULES:
        if name != leaf:
            continue
        if hint is not None and hint not in parents:
            continue
        if len(axes) > len(shape):      # stacked leaves only grow rank
            continue
        return axes
    return ()  # replicated


def logical_to_spec(rules: LogicalRules, logical_axes: Sequence[Optional[str]],
                    shape: Sequence[int]) -> P:
    return rules.spec(tuple(logical_axes), tuple(shape))


def param_specs(rules: LogicalRules, params_tree) -> Any:
    """Map a params pytree (arrays or ShapeDtypeStructs) to PartitionSpecs."""

    def one(path, leaf):
        names = _path_names(path)
        base = _base_axes(names, leaf.shape)
        pad = len(leaf.shape) - len(base)
        axes = (None,) * pad + tuple(base)
        return rules.spec(axes, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params_tree)


_BATCH_AXES = {
    "tokens": ("batch", None),
    "targets": ("batch", None),
    "loss_mask": ("batch", None),
    "prompt_lengths": ("batch",),
    "frames": ("batch", None, None),
    "image_embeds": ("batch", None, None),
}


def batch_specs(rules: LogicalRules, batch_tree) -> Any:
    def one(path, leaf):
        names = _path_names(path)
        axes = _BATCH_AXES.get(names[-1], ("batch",) + (None,) * (len(leaf.shape) - 1))
        return rules.spec(axes, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, batch_tree)


_CACHE_AXES = {
    # stacked over layers: [L, B, S, KV, hd]
    "k": (None, "batch", "kv_seq", "kv_heads", None),
    "v": (None, "batch", "kv_seq", "kv_heads", None),
    "xk": (None, "batch", "enc_seq", "kv_heads", None),
    "xv": (None, "batch", "enc_seq", "kv_heads", None),
    "attn_k": (None, "batch", "kv_seq", "kv_heads", None),
    "attn_v": (None, "batch", "kv_seq", "kv_heads", None),
    "rec_h": (None, None, "batch", "lru"),
    "rec_conv": (None, None, "batch", None, "lru"),
    "tail_h": (None, "batch", "lru"),
    "tail_conv": (None, "batch", None, "lru"),
    "wkv": (None, "batch", "heads", None, None),
    "tm_shift": (None, "batch", None),
    "cm_shift": (None, "batch", None),
    "lengths": ("batch",),
}


def cache_specs_tree(rules: LogicalRules, cache_tree) -> Any:
    def one(path, leaf):
        names = _path_names(path)
        axes = _CACHE_AXES.get(names[-1])
        if axes is None:
            axes = (None,) * len(leaf.shape)
        return rules.spec(axes, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def maybe_gather_params(layer_params) -> Any:
    """H2 (§Perf): explicit weight-gather FSDP.

    GSPMD's default handling of fsdp-sharded weights in layer matmuls is to
    compute with the contraction dim sharded and ALL-REDUCE the activation
    partial sums — activations are far larger than weights, so train steps
    become collective-bound (llama3-405b train: 2988 s collective term).
    Annotating the layer's weights as fsdp-unsharded at the top of the
    (remat'd) layer body makes XLA ALL-GATHER the weights once per layer
    use instead; the gradient transpose becomes a reduce-scatter — the
    standard ZeRO-3 schedule. No-op unless the ``gather_weights`` flag is
    on and a rules context is active.
    """
    from repro import flags
    from repro.sharding.context import current_rules
    rules = current_rules()
    if rules is None or not flags.enabled("gather_weights"):
        return layer_params

    def one(path, leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return leaf
        names = _path_names(path)
        base = _base_axes(names, leaf.shape)
        if "fsdp" not in base:
            return leaf
        if "experts" in base:
            # H2 finding (measured): gathering expert weights destroys the
            # expert-parallel schedule — qwen3-moe train compute blew up
            # 5.2 s -> 49.5 s with useful ratio 0.06. Experts stay sharded.
            return leaf
        pad = len(leaf.shape) - len(base)
        axes = (None,) * pad + tuple(None if a == "fsdp" else a for a in base)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(rules.mesh, rules.spec(axes, leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, layer_params)


def shard_like_params(tree) -> Any:
    """Constrain a params-shaped pytree (e.g. gradient accumulators) to the
    parameter sharding (§Perf H2 iter 3: unannotated f32 grad-accumulation
    buffers made GSPMD replicate them — a full-weight f32 all-reduce per
    layer per microbatch). No-op outside a rules context."""
    from repro.sharding.context import current_rules
    rules = current_rules()
    if rules is None:
        return tree
    specs = param_specs(rules, tree)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, s)),
        tree, specs)


def as_shardings(rules: LogicalRules, spec_tree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))
