from repro.sharding.context import (
    LogicalRules, annotate, use_rules, current_rules,
)
from repro.sharding.specs import (
    param_specs, batch_specs, cache_specs_tree, logical_to_spec,
)
