"""Qwen3-MoE-235B-A22B — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B family]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment)",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,          # Qwen3 uses head_dim=128 decoupled from d_model
    d_ff=1536,             # per-expert intermediate width
    vocab_size=151_936,
    num_experts=128,
    num_experts_per_tok=8,
    qk_norm=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
)
