"""Assigned input shapes.

Each shape pairs a (seq_len, global_batch) with an execution *kind*:

- ``train``   -> lowers ``train_step`` (forward + backward + optimizer)
- ``prefill`` -> lowers ``prefill``   (forward, fills the KV cache)
- ``decode``  -> lowers ``serve_step`` (ONE new token vs a seq_len cache)

``long_500k`` additionally requires a sub-quadratic decode path; archs whose
config lacks one skip it (recorded, not silently dropped).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown input shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]
