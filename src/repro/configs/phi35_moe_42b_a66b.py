"""Phi-3.5-MoE — 16-expert top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,             # per-expert
    vocab_size=32_064,
    num_experts=16,
    num_experts_per_tok=2,
    rope_theta=10_000.0,
    norm_eps=1e-5,
)
