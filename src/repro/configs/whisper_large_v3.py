"""Whisper-large-v3 — encoder-decoder ASR backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the assignment: the
encoder consumes precomputed frame embeddings [B, 1500, 1280] supplied by
``input_specs()``. Decode shapes exercise the decoder (self-attn cache +
fixed cross-attention over 1500 encoder states). ``long_500k`` is skipped
(enc-dec; the decoder operates in a ~448-token regime).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356 (Whisper); large-v3 card",
    num_layers=32,          # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,        # MHA
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,      # padded to 51968 for TP
    cross_attention=True,
    rope_theta=10_000.0,    # unused by learned-pos encoder; decoder uses rope here
    norm_eps=1e-5,
    tie_embeddings=True,
)
