"""RWKV6-7B (Finch) — attention-free, data-dependent decay [arXiv:2404.05892].

Time-mix state is O(1) in sequence length, so all decode shapes including
``long_500k`` run natively. heads = d_model / 64 = 64.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892 (RWKV-6 Finch)",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # = d_model / rwkv_head_dim
    num_kv_heads=64,
    head_dim=64,
    rwkv_head_dim=64,
    d_ff=14_336,
    vocab_size=65_536,
    norm_eps=1e-5,
)
