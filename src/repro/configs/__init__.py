"""Config registry: ``get_config(arch_id)`` for every assigned architecture.

Architecture IDs use the assignment's dashed spelling (e.g.
``qwen3-moe-235b-a22b``); module names use underscores.
"""

from repro.configs.base import ModelConfig, reduce_for_smoke, pad_to
from repro.configs.shapes import (
    SHAPES, InputShape, get_shape, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)

from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3_moe
from repro.configs.llama3_405b import CONFIG as _llama3_405b
from repro.configs.phi35_moe_42b_a66b import CONFIG as _phi35_moe
from repro.configs.deepseek_67b import CONFIG as _deepseek_67b
from repro.configs.minicpm_2b import CONFIG as _minicpm_2b
from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma_9b
from repro.configs.whisper_large_v3 import CONFIG as _whisper_large_v3
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.internvl2_2b import CONFIG as _internvl2_2b
from repro.configs.rwkv6_7b import CONFIG as _rwkv6_7b
from repro.configs.max_demo import SENTIMENT as _max_sentiment, CAPTION as _max_caption

# The 10 assigned architectures (the benchmark/dry-run population).
ASSIGNED: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _qwen3_moe,
        _llama3_405b,
        _phi35_moe,
        _deepseek_67b,
        _minicpm_2b,
        _recurrentgemma_9b,
        _whisper_large_v3,
        _qwen3_4b,
        _internvl2_2b,
        _rwkv6_7b,
    )
}

# Paper demo assets (CPU-runnable).
DEMOS: dict[str, ModelConfig] = {
    c.name: c for c in (_max_sentiment, _max_caption)
}

CONFIGS: dict[str, ModelConfig] = {**ASSIGNED, **DEMOS}

for _c in CONFIGS.values():
    _c.validate()


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown architecture {name!r}; have {sorted(CONFIGS)}")
    return CONFIGS[name]


def list_archs(assigned_only: bool = True) -> list[str]:
    return sorted(ASSIGNED if assigned_only else CONFIGS)


def applicable_shapes(cfg: ModelConfig) -> dict[str, bool]:
    """Which of the 4 assigned shapes apply to this arch (False = recorded skip)."""
    out = {"train_4k": True, "prefill_32k": True, "decode_32k": True}
    out["long_500k"] = cfg.supports_long_context
    return out
