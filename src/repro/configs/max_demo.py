"""The paper's own demo assets, in miniature.

MAX (CIKM'19) demonstrates a text-sentiment classifier (Fig. 3 JSON), an
object detector, and an image-caption generator (Show-and-Tell). We mirror
the text-shaped two as small, CPU-runnable assets so the examples and HTTP
demos exercise the exact paper flows:

- ``max-sentiment``: tiny causal LM scored as a 2-way classifier; its
  prediction envelope reproduces the paper's Fig. 3 JSON verbatim shape:
  ``{"status": "ok", "predictions": [[{"positive": p, "negative": n}]]}``.
- ``max-caption``: tiny encoder-decoder consuming stub image patch
  embeddings (the Show-and-Tell analogue).
"""

from repro.configs.base import ModelConfig

SENTIMENT = ModelConfig(
    name="max-sentiment",
    family="dense",
    source="MAX demo asset (CIKM'19 Fig. 3, MAX-Text-Sentiment-Classifier)",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    tie_embeddings=True,
)

CAPTION = ModelConfig(
    name="max-caption",
    family="vlm",
    source="MAX demo asset (CIKM'19 Fig. 2b, Show-and-Tell caption generator)",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    num_image_tokens=8,
)
