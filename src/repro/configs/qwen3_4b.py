"""Qwen3-4B — dense GQA with qk_norm [hf:Qwen/Qwen3-8B family]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (4B sibling per assignment)",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    tie_embeddings=True,
    sliding_window=4096,   # enables long_500k decode (beyond-paper variant)
)
