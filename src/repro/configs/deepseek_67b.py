"""DeepSeek-67B — dense llama-arch GQA [arXiv:2401.02954]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    source="arXiv:2401.02954 (DeepSeek LLM)",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab_size=102_400,
    rope_theta=10_000.0,
    norm_eps=1e-5,
)
