"""RecurrentGemma-9B — RG-LRU + local attention, 2:1 pattern [arXiv:2402.19427].

38 layers = 12 x (rec, rec, attn) blocks + 2 tail recurrent layers.
Local attention window 2048; MQA (kv=1). Long-context decode is native:
RG-LRU state is O(1) in sequence length and the attention cache is bounded
by the window.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427 (Griffin/RecurrentGemma)",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,        # MQA
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    local_attn_window=2048,
    rope_theta=10_000.0,
    norm_eps=1e-6,
    tie_embeddings=True,
)
