"""InternVL2-2B — InternViT + InternLM2 VLM [arXiv:2404.16821].

The InternViT vision encoder + MLP projector is a STUB per the assignment:
``input_specs()`` supplies 256 precomputed patch embeddings [B, 256, 2048]
that are prepended to the token embeddings. This module implements the
InternLM2-like language decoder that consumes them.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL 1.5/2); internlm2-chat-1_8b LM",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_553,     # padded to 92672 for TP
    num_image_tokens=256,
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
)
