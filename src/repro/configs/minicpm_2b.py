"""MiniCPM-2B — llama-like dense, WSD schedule [arXiv:2404.06395].

36 heads do not divide the 16-way ``model`` axis, so attention weights stay
replicated over TP (MLP still TP-sharded) — see sharding/rules.py.
``long_500k`` is served via the sliding-window variant.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    source="arXiv:2404.06395 (MiniCPM)",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,       # MHA
    head_dim=64,
    d_ff=5760,
    vocab_size=122_753,    # padded to 122880 for TP; logical kept for loss
    rope_theta=10_000.0,
    norm_eps=1e-5,
    tie_embeddings=True,
    lr_schedule="wsd",
    sliding_window=4096,   # enables long_500k decode (beyond-paper variant)
)
