"""Model configuration system.

Every architecture in the exchange is described by a single frozen
``ModelConfig``. Configs are *data*: model code in ``repro.models`` consumes
them, the sharding layer derives PartitionSpecs from them, and the MAX
registry exposes them as discoverable assets.

Conventions
-----------
- ``vocab_size`` is the *logical* vocabulary from the source model card;
  ``padded_vocab_size`` rounds up to a multiple of ``VOCAB_PAD`` so the
  embedding/LM-head shard evenly over the 16-way ``model`` mesh axis.
- For MoE configs ``d_ff`` is the *per-expert* hidden width (matching the
  assignment table) and every layer is an MoE layer unless
  ``moe_layer_period`` says otherwise.
- ``block_pattern`` describes hybrid stacking (e.g. RecurrentGemma's
  recurrent/recurrent/attention blocks). Empty pattern = uniform stack.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

VOCAB_PAD = 256  # multiple that keeps vocab shardable over 16-way TP


def pad_to(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    # -- identity -----------------------------------------------------------
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    source: str = ""                 # citation for the config numbers

    # -- core transformer dims ---------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                # explicit; NOT always d_model//num_heads
    d_ff: int = 0                    # dense MLP width, or per-expert width
    vocab_size: int = 0              # logical vocab

    # -- attention ----------------------------------------------------------
    qk_norm: bool = False            # Qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # enables long-context decode
    attn_logit_softcap: Optional[float] = None

    # -- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.001
    router_z_loss_coef: float = 0.0001

    # -- hybrid (RecurrentGemma) ---------------------------------------------
    block_pattern: Tuple[str, ...] = ()    # e.g. ("rec", "rec", "attn")
    lru_width: int = 0                     # RG-LRU recurrence width
    local_attn_window: int = 0             # window for hybrid local attention

    # -- SSM / RWKV6 ----------------------------------------------------------
    rwkv_head_dim: int = 64

    # -- encoder-decoder (Whisper) --------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0                   # fixed frame count (1500)
    cross_attention: bool = False
    decoder_only_decode: bool = True       # decode shapes exercise decoder

    # -- VLM -------------------------------------------------------------------
    num_image_tokens: int = 0              # stub patch embeddings prepended

    # -- misc -------------------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Schedule hint consumed by training/schedule.py (MiniCPM uses WSD).
    lr_schedule: str = "cosine"            # cosine | wsd

    # ======================================================================
    # derived quantities
    # ======================================================================
    @property
    def padded_vocab_size(self) -> int:
        return pad_to(self.vocab_size, VOCAB_PAD)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if the architecture can decode at 512k (sub-quadratic path)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    # -- hybrid stacking ------------------------------------------------------
    @property
    def num_pattern_blocks(self) -> int:
        if not self.block_pattern:
            return 0
        return self.num_layers // len(self.block_pattern)

    @property
    def num_tail_layers(self) -> int:
        """Layers left over after whole pattern blocks (RG-9b: 38 = 12*3 + 2).

        Tail layers are recurrent (the pattern's majority type).
        """
        if not self.block_pattern:
            return 0
        return self.num_layers - self.num_pattern_blocks * len(self.block_pattern)

    # -- parameter counting (analytic, used by roofline) -----------------------
    def attn_params(self) -> int:
        d, q, kv = self.d_model, self.q_dim, self.kv_dim
        return d * q + 2 * d * kv + q * d  # wq, wk, wv, wo

    def mlp_params(self) -> int:
        # gated SwiGLU: up, gate, down
        return 3 * self.d_model * self.d_ff

    def moe_layer_params(self) -> int:
        return self.num_experts * self.mlp_params() + self.d_model * self.num_experts

    def rglru_params(self) -> int:
        d, w = self.d_model, self.lru_width
        # in/out projections (x2 gated branches) + recurrence gates + diag a
        return 2 * d * w + w * d + 2 * w * w // 8 + 2 * w  # block-diag gates (8 blocks)

    def rwkv_layer_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,g,o projections + decay/bonus + lora mixers
        tm = 5 * d * d + 2 * d + 6 * d * 96
        cm = 2 * d * self.d_ff + self.d_ff * 0  # rwkv6 channel mix: k, v (+r gate d*d)
        cm = d * self.d_ff + self.d_ff * d + d * d
        return tm + cm

    def param_count(self) -> int:
        """Analytic total parameter count (embedding + layers + head)."""
        d = self.d_model
        emb = self.padded_vocab_size * d
        head = 0 if self.tie_embeddings else self.padded_vocab_size * d
        total = emb + head

        if self.family == "ssm":
            total += self.num_layers * self.rwkv_layer_params()
            return total

        if self.family == "hybrid":
            n_attn = sum(
                1 for i in range(self.num_layers)
                if self.layer_type(i) == "attn"
            )
            n_rec = self.num_layers - n_attn
            per_mlp = self.mlp_params()
            total += n_attn * (self.attn_params() + per_mlp)
            total += n_rec * (self.rglru_params() + per_mlp)
            return total

        per_layer = self.attn_params()
        per_layer += self.moe_layer_params() if self.is_moe else self.mlp_params()
        total += self.num_layers * per_layer
        if self.family == "audio":
            # encoder stack + decoder cross-attention
            enc = self.encoder_layers * (self.attn_params() + self.mlp_params())
            cross = self.num_layers * self.attn_params()
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        emb = self.padded_vocab_size * d
        head = 0 if self.tie_embeddings else self.padded_vocab_size * d
        per_layer = self.attn_params()
        per_layer += self.num_experts_per_tok * self.mlp_params()
        per_layer += self.d_model * self.num_experts  # router
        return emb + head + self.num_layers * per_layer

    def layer_type(self, i: int) -> str:
        """Layer type at depth i: 'attn' | 'rec' | 'moe' | 'dense' | 'rwkv'."""
        if self.family == "ssm":
            return "rwkv"
        if self.block_pattern:
            if i >= self.num_pattern_blocks * len(self.block_pattern):
                return self.block_pattern[0]  # tail layers take majority type
            return self.block_pattern[i % len(self.block_pattern)]
        return "moe" if self.is_moe else "attn"

    def validate(self) -> None:
        assert self.d_model > 0 and self.num_layers > 0
        assert self.vocab_size > 0
        if self.family != "ssm":
            assert self.num_heads > 0 and self.head_dim > 0
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
                f"{self.name}: heads {self.num_heads} not grouped by kv "
                f"{self.num_kv_heads}"
            )
        if self.is_moe:
            assert 0 < self.num_experts_per_tok <= self.num_experts
        if self.family == "hybrid":
            assert self.block_pattern and self.lru_width > 0
        if self.family == "audio":
            assert self.encoder_layers > 0 and self.encoder_seq > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# smoke-test reduction
# ---------------------------------------------------------------------------

def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts.

    The reduced config preserves the family's structure (GQA grouping, MoE
    top-k, hybrid pattern, enc-dec, VLM stub) so the smoke test exercises the
    same code paths as the full config.
    """
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=256,
        d_ff=512,
        vocab_size=512,
    )
    if cfg.family == "ssm":
        kw.update(num_heads=256 // cfg.rwkv_head_dim,
                  num_kv_heads=256 // cfg.rwkv_head_dim, head_dim=cfg.rwkv_head_dim)
    else:
        kw.update(num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 2) or 1,
                  head_dim=64)
        if cfg.num_kv_heads == cfg.num_heads:   # MHA stays MHA
            kw["num_kv_heads"] = 4
        if cfg.num_kv_heads == 1:               # MQA stays MQA
            kw["num_kv_heads"] = 1
    if cfg.is_moe:
        kw.update(num_experts=4, num_experts_per_tok=min(2, cfg.num_experts_per_tok))
    if cfg.family == "hybrid":
        # one (rec, attn) miniature of the pattern -> 2 layers
        kw.update(block_pattern=("rec", "attn"), num_layers=2, lru_width=256,
                  local_attn_window=min(cfg.local_attn_window, 128) or 64)
    if cfg.family == "audio":
        kw.update(encoder_layers=2, encoder_seq=64)
    if cfg.family == "vlm":
        kw.update(num_image_tokens=8)
    if cfg.sliding_window is not None:
        kw.update(sliding_window=64)
    return cfg.replace(**kw)
