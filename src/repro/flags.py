"""Global optimization flags.

The paper-faithful BASELINE configuration runs with all optimizations off;
the optimized configuration (EXPERIMENTS.md §Perf) turns them on. Flags are
read at trace time, so flipping them changes the lowered HLO.

- chunked_wkv   : RWKV6 chunked-parallel WKV instead of per-token scan (H1)
- carry_cache   : decode KV cache in scan carry (in-place) vs xs/ys (H3.2)
- donate        : donate train state / decode cache buffers (H3.1)
- gather_weights: all-gather FSDP-sharded weights per layer instead of
                  letting GSPMD partial-sum all-reduce activations (H2)
"""

from __future__ import annotations

_FLAGS = {
    "chunked_wkv": True,
    "carry_cache": True,
    "donate": True,
    "gather_weights": False,   # opt-in (H2; interacts with XLA's own choices)
    "uniform_decode": False,   # scalar-index cache writes (lockstep decode)
}


def enabled(name: str) -> bool:
    return _FLAGS[name]


def set_flag(name: str, value: bool):
    assert name in _FLAGS, name
    _FLAGS[name] = value


def set_all(**kw):
    for k, v in kw.items():
        set_flag(k, v)


def snapshot() -> dict:
    return dict(_FLAGS)
