"""Production mesh construction.

Importing this module never touches jax device state — meshes are built by
functions only (the dry-run forces 512 host devices via XLA_FLAGS before
any jax import; tests and benches see the real single device).

Topology (TPU v5e): one pod = 256 chips as a 16x16 mesh
``("data", "model")``; two pods add a leading ``pod`` axis
``(2, 16, 16) = ("pod", "data", "model")``. The ``pod`` axis carries only
data parallelism (per-pod gradient all-reduce crosses the inter-pod links
once per step), composing with ``data`` via the logical ``batch``/``fsdp``
rules.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")

# v5e hardware constants (roofline):
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
HBM_PER_CHIP = 16 * 2**30         # 16 GiB


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under launch/dryrun.py (forces 512 host devices)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(shape=(2, 4), axes=("data", "model")) -> Mesh:
    """Small mesh for subprocess sharding tests (8 forced host devices)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def num_chips(mesh: Mesh) -> int:
    return mesh.devices.size


# -- topology geometry (pure; no device access) -----------------------------

#: rows per pod and chips per row in the production topology — the
#: coordinate system ``mesh_slice`` strings ("pod0/rows0-7") address.
ROWS_PER_POD = SINGLE_POD_SHAPE[0]
CHIPS_PER_ROW = SINGLE_POD_SHAPE[1]
NUM_PODS = MULTI_POD_SHAPE[0]


def pod_row_chips(pod: int, row_lo: int, row_hi: int) -> tuple:
    """Flat chip indices of rows ``[row_lo, row_hi]`` (inclusive) of
    ``pod`` in the production topology. Chips are row-major within a pod;
    pods are consecutive ``ROWS_PER_POD * CHIPS_PER_ROW``-chip blocks —
    the same ordering ``make_production_mesh`` lays devices out in, so a
    row range is a contiguous, disjointly-partitionable device span."""
    if not 0 <= pod < NUM_PODS:
        raise ValueError(f"pod {pod} out of range (topology has "
                         f"{NUM_PODS} pods)")
    if not 0 <= row_lo <= row_hi < ROWS_PER_POD:
        raise ValueError(
            f"rows {row_lo}-{row_hi} out of range (each pod has "
            f"{ROWS_PER_POD} rows)")
    base = pod * ROWS_PER_POD * CHIPS_PER_ROW
    return tuple(range(base + row_lo * CHIPS_PER_ROW,
                       base + (row_hi + 1) * CHIPS_PER_ROW))
