"""Roofline analysis over persisted dry-run records.

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s        [s]
    memory term     = HLO_HBM_bytes_per_chip / HBM_bw         [s]
    collective term = wire_bytes_per_chip / ICI link bw       [s]
(HLO quantities come from launch/hlo_analysis.py — post-SPMD per-device
module with loop trip-count scaling.)

Also reported: MODEL_FLOPS = 6·N·D (train; 6·N_active·D for MoE) or 2·N·D
(prefill/decode), the useful-compute ratio MODEL_FLOPS/HLO_FLOPs (remat &
dispatch waste shows up here), the dominant term, and a heuristic
suggestion for what would move the dominant term down.

Usage:
    python -m repro.launch.roofline --records experiments/dryrun [--mesh single]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.configs.shapes import get_shape
from repro.launch.mesh import HBM_BW, HBM_PER_CHIP, ICI_BW, PEAK_FLOPS_BF16


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops_per_chip: float = 0.0
    useful_ratio: float = 0.0
    hbm_gib_per_chip: float = 0.0
    fits: bool = True
    note: str = ""

    @property
    def step_s(self) -> float:
        """Lower-bound step time if terms overlapped perfectly = max;
        we report the max (roofline convention)."""
        return max(self.compute_s, self.memory_s, self.collective_s)


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def suggest(row: RooflineRow, rec: dict) -> str:
    cb = rec.get("hlo_cost", {}).get("collective_bytes", {})
    if row.dominant == "collective":
        top = max(cb, key=cb.get) if cb else "?"
        if top == "all-reduce":
            return ("all-reduce dominated: fsdp contraction-dim partial sums "
                    "-> gather weights per layer instead (see §Perf)")
        if top == "all-gather":
            return "all-gather dominated: cache/params gathered; reshard or overlap"
        return f"{top} dominated: reshard to shrink resharding traffic"
    if row.dominant == "memory":
        if row.useful_ratio < 0.5:
            return "HBM traffic >> useful compute: fuse/remat-tune the hot loop"
        return "bandwidth-bound (expected for decode): shrink cache dtype/layout"
    if row.useful_ratio < 0.6:
        return "compute-bound with low useful ratio: cut remat recompute"
    return "compute-bound near peak: healthy"


def load_rows(records_dir: str, mesh: Optional[str] = None) -> List[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(records_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("tag"):
            continue                      # perf-iteration records live in §Perf
        if mesh and rec["mesh"] != mesh:
            continue
        row = RooflineRow(rec["arch"], rec["shape"], rec["mesh"],
                          rec["status"])
        if rec["status"] == "skipped":
            row.note = rec.get("reason", "")
            rows.append(row)
            continue
        if rec["status"] != "ok":
            row.note = rec.get("error", "")[:80]
            rows.append(row)
            continue
        hc = rec["hlo_cost"]
        row.compute_s = hc["flops"] / PEAK_FLOPS_BF16
        row.memory_s = hc["hbm_bytes"] / HBM_BW
        row.collective_s = hc["wire_bytes"] / ICI_BW
        terms = {"compute": row.compute_s, "memory": row.memory_s,
                 "collective": row.collective_s}
        row.dominant = max(terms, key=terms.get)
        n_chips = rec.get("num_chips", 256)
        row.model_flops_per_chip = model_flops(rec["arch"], rec["shape"]) / n_chips
        row.useful_ratio = (row.model_flops_per_chip / hc["flops"]
                            if hc["flops"] else 0.0)
        mem = rec.get("memory", {})
        live = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0) \
            + (mem.get("output_bytes") or 0) - (mem.get("alias_bytes") or 0)
        row.hbm_gib_per_chip = live / 2**30
        row.fits = live <= HBM_PER_CHIP
        row.note = suggest(row, rec)
        rows.append(row)
    return rows


def fmt_s(x: float) -> str:
    if x == 0:
        return "-"
    if x < 1e-3:
        return f"{x * 1e6:.0f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def to_markdown(rows: List[RooflineRow]) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | dominant "
           "| useful | HBM/chip | fits | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.status == "skipped":
            lines.append(
                f"| {r.arch} | {r.shape} | {r.mesh} | — | — | — | — | — | — "
                f"| — | SKIP: {r.note[:60]} |")
            continue
        if r.status != "ok":
            lines.append(
                f"| {r.arch} | {r.shape} | {r.mesh} | — | — | — | — | — | — "
                f"| — | ERROR: {r.note} |")
            continue
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {fmt_s(r.compute_s)} "
            f"| {fmt_s(r.memory_s)} | {fmt_s(r.collective_s)} | "
            f"**{r.dominant}** | {r.useful_ratio:.2f} "
            f"| {r.hbm_gib_per_chip:.1f}GiB | {'y' if r.fits else '**N**'} "
            f"| {r.note} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = load_rows(args.records, args.mesh)
    md = to_markdown(rows)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
    print(md)


if __name__ == "__main__":
    main()
