"""Training launcher.

Two modes:
- default: REAL training on the local device(s) with a reduced (smoke) or
  demo config — runs on this CPU container;
- ``--dryrun``: AOT lower+compile of the full production config on the
  production mesh (delegates to launch/dryrun.py; run that module directly
  for the full sweep).

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch llama3-405b --dryrun
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="max-sentiment")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (CPU-runnable); implied unless --dryrun")
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile the FULL config on the production mesh")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.dryrun:
        # keep the device-count env dance inside dryrun's module
        import subprocess
        import sys
        raise SystemExit(subprocess.call([
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", args.arch, "--shape", "train_4k", "--mesh", "both"]))

    import jax
    import jax.numpy as jnp

    from repro.configs import CONFIGS, get_config
    from repro.configs.base import reduce_for_smoke
    from repro.models import build_model
    from repro.training import (
        DataConfig, adamw, batches, init_train_state, make_schedule,
        make_train_step, save_checkpoint,
    )

    cfg = get_config(args.arch)
    if args.arch not in ("max-sentiment", "max-caption"):
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg)
    opt = adamw(make_schedule(cfg.lr_schedule, peak_lr=args.peak_lr,
                              warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt,
                                   num_microbatches=args.microbatches))
    data = batches(DataConfig(seq_len=args.seq_len,
                              global_batch=args.global_batch,
                              vocab_size=cfg.vocab_size))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"[train] {cfg.name}: {n_params/1e6:.2f}M params, "
          f"{args.steps} steps, schedule={cfg.lr_schedule}")
    t0 = time.perf_counter()
    for i in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, m = step(state, b)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"[train] step {i:5d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e}")
    dt = time.perf_counter() - t0
    toks = args.steps * args.global_batch * args.seq_len
    print(f"[train] done: {dt:.1f}s, {toks/dt:.0f} tok/s")
    if args.ckpt:
        save_checkpoint(args.ckpt, state.params, step=args.steps)
        print(f"[train] checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
