"""Serving launcher: start the MAX REST stack (v1 + v2 surfaces).

    PYTHONPATH=src python -m repro.launch.serve --port 8080 \
        --deploy max-sentiment --deploy qwen3-4b --service auto

``--service`` picks the execution strategy behind each deployment:
``sync`` (per-request, v1 semantics), ``batched`` (continuous batching —
concurrent HTTP predicts coalesce into engine decode batches), or ``auto``
(batched for generative wrappers, sync otherwise).

Deployed assets use reduced (CPU-runnable) configs by default; on a pod the
same launcher would pass ``smoke=False`` build kwargs and a mesh slice per
deployment (core/deployment.py).
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--deploy", action="append", default=[],
                    help="asset id to deploy at startup (repeatable)")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--service", default="auto",
                    choices=["sync", "batched", "auto"],
                    help="inference service mode for deployments")
    ap.add_argument("--batch-window-ms", type=float, default=10.0,
                    help="coalescing window for the batched service")
    ap.add_argument("--duration", type=float, default=None,
                    help="serve for N seconds then exit (default: forever)")
    args = ap.parse_args()

    import repro.core.assets  # noqa: F401 — populate the exchange
    from repro.core import EXCHANGE, MAXServer

    server = MAXServer(
        host=args.host, port=args.port,
        build_kw={"max_seq": args.max_seq, "max_batch": args.max_batch},
        service_mode=args.service,
        service_kw={"batch_window_s": args.batch_window_ms / 1e3})
    server.start()
    print(f"[serve] Model Asset eXchange at {server.url}")
    print(f"[serve] {len(EXCHANGE)} assets registered; "
          f"GET /models, /v2/models, /v2/routes, /swagger.json")
    print(f"[serve] service mode: {args.service} "
          f"(window {args.batch_window_ms:.0f}ms)")
    for asset_id in args.deploy:
        t0 = time.perf_counter()
        dep = server.manager.deploy(asset_id, **server.build_kw)
        print(f"[serve] deployed {asset_id} [{dep.service.kind}] "
              f"({time.perf_counter() - t0:.1f}s)")
    try:
        if args.duration:
            time.sleep(args.duration)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
        print("[serve] stopped")


if __name__ == "__main__":
    main()
