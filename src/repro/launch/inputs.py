"""ShapeDtypeStruct input stand-ins for every (arch x shape) pair.

``input_specs(cfg, shape)`` returns (kind, kwargs) where kwargs are pytrees
of ``jax.ShapeDtypeStruct`` — weak-type-correct, shardable, zero allocation.
Decode shapes produce the ``serve_step`` signature (one token vs a
``seq_len`` cache); train/prefill produce batch dicts.

Modality frontends are stubs per the assignment: audio supplies
``frames [B, 1500, d]``, VLM supplies ``image_embeds [B, 256, d]``.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import build_model

I32 = jnp.int32
BF16 = jnp.bfloat16
F32 = jnp.float32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    batch = {
        "tokens": sds((B, S), I32),
        "targets": sds((B, S), I32),
        "loss_mask": sds((B, S), F32),
    }
    if cfg.family == "audio":
        batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), BF16)
    if cfg.family == "vlm":
        batch["image_embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model), BF16)
    return batch


def prefill_batch_specs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    batch = {"tokens": sds((B, S), I32)}
    if cfg.family == "audio":
        batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), BF16)
    if cfg.family == "vlm":
        batch["image_embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model), BF16)
    return batch


def params_specs(cfg: ModelConfig, param_dtype=BF16):
    model = build_model(cfg, param_dtype=param_dtype)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def cache_specs(cfg: ModelConfig, B: int, S: int, cache_dtype=BF16):
    model = build_model(cfg, cache_dtype=cache_dtype)
    return jax.eval_shape(lambda: model.init_cache(B, S))


def input_specs(cfg: ModelConfig, shape: InputShape,
                param_dtype=BF16) -> Tuple[str, Dict[str, Any]]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return "train", {"batch": train_batch_specs(cfg, B, S)}
    if shape.kind == "prefill":
        return "prefill", {"batch": prefill_batch_specs(cfg, B, S)}
    if shape.kind == "decode":
        return "decode", {
            "cache": cache_specs(cfg, B, S),
            "tokens": sds((B,), I32),
        }
    raise ValueError(shape.kind)
