"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

Proves the distribution config is coherent without real hardware: AOT
``.lower().compile()`` against ShapeDtypeStruct inputs on 512 forced host
devices, then records memory analysis, XLA cost analysis, and the
trip-count-scaled HLO cost model (launch/hlo_analysis.py) to JSON for the
roofline tables.

Usage:
    python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
    python -m repro.launch.dryrun --sweep              # all pairs x both meshes
    python -m repro.launch.dryrun --sweep --mesh single
"""

# MUST be first — before ANY jax-importing module — jax locks the device
# count on first init. Do NOT set this in conftest.py/pyproject: smoke tests
# and benches must see 1 device.
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512")

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import flags
from repro.configs import applicable_shapes, get_config, list_archs
from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, get_shape
from repro.launch.hlo_analysis import analyze
from repro.launch.inputs import input_specs, params_specs, train_batch_specs
from repro.launch.mesh import make_production_mesh, num_chips
from repro.models import build_model
from repro.sharding import LogicalRules, use_rules
from repro.sharding.specs import batch_specs, cache_specs_tree, param_specs
from repro.training import adamw, make_schedule
from repro.training.trainer import TrainState, make_train_step

BF16 = jnp.bfloat16

BIG_MODEL_B = 60e9      # >=: bf16 optimizer moments (HBM budget, DESIGN.md)


def _num_microbatches(cfg: ModelConfig, global_batch: int, mesh) -> int:
    """Baseline: per-device micro batch of 1 on the data axes."""
    data_total = mesh.shape["data"] * mesh.shape.get("pod", 1)
    nm = max(1, global_batch // data_total)
    while global_batch % nm:
        nm -= 1
    return nm


def _sharding_tree(rules: LogicalRules, spec_tree):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def lower_pair(cfg: ModelConfig, shape_name: str, *, multi_pod: bool,
               num_microbatches: Optional[int] = None,
               rule_overrides: Optional[dict] = None,
               cache_dtype=BF16):
    """Returns (lowered, rules, meta) for one (arch, shape, mesh)."""
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = LogicalRules(mesh, rule_overrides)
    model = build_model(cfg, param_dtype=BF16, remat=True,
                        cache_dtype=cache_dtype)

    kind, kwargs = input_specs(cfg, shape)
    if kind == "decode" and cache_dtype != BF16:
        from repro.launch.inputs import cache_specs
        kwargs["cache"] = cache_specs(cfg, shape.global_batch, shape.seq_len,
                                      cache_dtype=cache_dtype)
    p_specs = params_specs(cfg)
    p_shard = _sharding_tree(rules, param_specs(rules, p_specs))

    meta: Dict[str, Any] = {"kind": kind, "mesh_axes": dict(mesh.shape)}

    with use_rules(rules), mesh:
        if kind == "train":
            nm = num_microbatches or _num_microbatches(
                cfg, shape.global_batch, mesh)
            meta["num_microbatches"] = nm
            moment_dtype = (jnp.bfloat16 if cfg.param_count() >= BIG_MODEL_B
                            else jnp.float32)
            meta["moment_dtype"] = str(jnp.dtype(moment_dtype))
            sched = make_schedule(cfg.lr_schedule, peak_lr=3e-4,
                                  warmup_steps=2000, total_steps=100_000)
            opt = adamw(sched, moment_dtype=moment_dtype)
            accum_dtype = (jnp.bfloat16 if cfg.param_count() >= BIG_MODEL_B
                           else jnp.float32)
            meta["accum_dtype"] = str(jnp.dtype(accum_dtype))
            state_specs = jax.eval_shape(
                lambda: TrainState(model.init(jax.random.PRNGKey(0)),
                                   opt.init(p_specs)))
            # optimizer moments shard exactly like their parameters
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.training.optimizer import AdamWState
            repl = NamedSharding(rules.mesh, P())
            moment_shard = _sharding_tree(rules, param_specs(rules, p_specs))
            state_shard = TrainState(
                p_shard, AdamWState(repl, moment_shard, moment_shard))
            b_specs = kwargs["batch"]
            b_shard = _sharding_tree(rules, batch_specs(rules, b_specs))
            step_fn = make_train_step(model, opt, num_microbatches=nm,
                                      accum_dtype=accum_dtype)
            donate = (0,) if flags.enabled("donate") else ()
            lowered = jax.jit(
                step_fn, in_shardings=(state_shard, b_shard),
                donate_argnums=donate,         # state buffers reused in-place
            ).lower(state_specs, b_specs)
        elif kind == "prefill":
            b_specs = kwargs["batch"]
            b_shard = _sharding_tree(rules, batch_specs(rules, b_specs))

            def prefill_fn(params, batch):
                return model.prefill(params, batch)

            lowered = jax.jit(
                prefill_fn, in_shardings=(p_shard, b_shard),
            ).lower(p_specs, b_specs)
        else:  # decode
            c_specs = kwargs["cache"]
            c_shard = _sharding_tree(rules, cache_specs_tree(rules, c_specs))
            from jax.sharding import NamedSharding, PartitionSpec as P
            t_shard = NamedSharding(
                rules.mesh, rules.spec(("batch",), kwargs["tokens"].shape))

            def serve_step(params, cache, tokens):
                return model.decode_step(params, cache, tokens)

            donate = (1,) if flags.enabled("donate") else ()
            lowered = jax.jit(
                serve_step, in_shardings=(p_shard, c_shard, t_shard),
                donate_argnums=donate,         # cache updates in-place
            ).lower(p_specs, c_specs, kwargs["tokens"])

    meta["sharding_fallbacks"] = sorted(set(rules.fallbacks))
    return lowered, rules, meta


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            out_dir: Optional[str] = None,
            num_microbatches: Optional[int] = None,
            rule_overrides: Optional[dict] = None,
            cache_dtype=BF16,
            tag: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    mesh_name = "multi" if multi_pod else "single"
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    # The sliding-window config on dense archs is the *long-context variant*
    # (enables long_500k). All other shapes run the faithful full-attention
    # model from the source model card.
    if cfg.family == "dense" and cfg.sliding_window is not None:
        if shape_name == "long_500k":
            record["variant"] = "sliding_window"
        else:
            cfg = cfg.replace(sliding_window=None)
    if not applicable_shapes(cfg).get(shape_name, True):
        record.update(status="skipped",
                      reason="pure full-attention / enc-dec arch: no "
                             "sub-quadratic long-context decode path")
        _write(record, out_dir, tag)
        return record

    record["flags"] = flags.snapshot()
    t0 = time.time()
    try:
        lowered, rules, meta = lower_pair(
            cfg, shape_name, multi_pod=multi_pod,
            num_microbatches=num_microbatches, rule_overrides=rule_overrides,
            cache_dtype=cache_dtype)
        record["cache_dtype"] = str(jnp.dtype(cache_dtype))
        record.update(meta)
        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
        ca = compiled.cost_analysis() or {}
        record["xla_cost_analysis"] = {
            k: ca[k] for k in ("flops", "bytes accessed") if k in ca}
        n = num_chips(make_production_mesh(multi_pod=multi_pod))
        record["num_chips"] = n
        t2 = time.time()
        cost = analyze(compiled.as_text(), n)
        record["analyze_s"] = round(time.time() - t2, 1)
        record["hlo_cost"] = cost.to_json()
        record["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record every failure mode
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    record["total_s"] = round(time.time() - t0, 1)
    _write(record, out_dir, tag)
    return record


def _write(record: Dict[str, Any], out_dir: Optional[str], tag: str = ""):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(
        out_dir,
        f"{record['arch']}_{record['shape']}_{record['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)


def _apply_profile(profile: str, shape_kind: str):
    """baseline = paper-faithful; opt = all validated optimizations."""
    if profile == "baseline":
        flags.set_all(chunked_wkv=False, carry_cache=False, donate=False,
                      gather_weights=False, uniform_decode=False)
        return BF16
    flags.set_all(chunked_wkv=True, carry_cache=True, donate=True,
                  gather_weights=True, uniform_decode=False)
    # fp8 KV cache for decode (H3 iter 4)
    return jnp.float8_e4m3fn if shape_kind == "decode" else BF16


def sweep(out_dir: str, *, meshes=("single", "multi"), archs=None,
          shapes=None, skip_existing: bool = True, profile: str = "opt"):
    archs = archs or list_archs()
    shapes = shapes or list(SHAPES)
    results = []
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                path = os.path.join(out_dir,
                                    f"{arch}_{shape_name}_{mesh_name}.json")
                if skip_existing and os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    if rec.get("status") in ("ok", "skipped"):
                        results.append(rec)
                        continue
                print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ...",
                      flush=True)
                cache_dtype = _apply_profile(
                    profile, get_shape(shape_name).kind)
                rec = run_one(arch, shape_name,
                              multi_pod=(mesh_name == "multi"),
                              cache_dtype=cache_dtype,
                              out_dir=out_dir)
                print(f"[dryrun]   -> {rec['status']} "
                      f"({rec.get('total_s', 0)}s) "
                      f"{rec.get('error', '')}", flush=True)
                results.append(rec)
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] sweep done: {ok} ok, {sk} skipped, {err} errors")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--no-skip-existing", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful baseline: all optimizations off")
    ap.add_argument("--profile", default="opt", choices=["baseline", "opt"])
    ap.add_argument("--gather-weights", action="store_true")
    args = ap.parse_args()
    if args.baseline:
        flags.set_all(chunked_wkv=False, carry_cache=False, donate=False,
                      gather_weights=False, uniform_decode=False)
    # uniform_decode stays OFF: both lockstep-write variants REFUTED
    # (GSPMD reshards traced-index writes on the model-sharded cache S dim;
    # see EXPERIMENTS.md §Perf H3 iters 3a/3b)
    if args.gather_weights:
        flags.set_flag("gather_weights", True)

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.sweep:
        archs = [args.arch] if args.arch else None
        shapes = [args.shape] if args.shape else None
        sweep(args.out, meshes=meshes, archs=archs, shapes=shapes,
              skip_existing=not args.no_skip_existing,
              profile="baseline" if args.baseline else args.profile)
        return
    assert args.arch and args.shape, "--arch/--shape required (or --sweep)"
    for mesh_name in meshes:
        rec = run_one(args.arch, args.shape, multi_pod=(mesh_name == "multi"),
                      out_dir=args.out,
                      num_microbatches=args.microbatches)
        mem = rec.get("memory", {})
        print(json.dumps({k: rec.get(k) for k in
                          ("arch", "shape", "mesh", "status", "error",
                           "compile_s")}, indent=1))
        if rec["status"] == "ok":
            print("  memory:", {k: f"{(v or 0)/2**30:.2f}GiB"
                                for k, v in mem.items() if v})
            print("  hlo flops:", f"{rec['hlo_cost']['flops']:.3e}",
                  " wire bytes:", f"{rec['hlo_cost']['wire_bytes']:.3e}")


if __name__ == "__main__":
    main()
