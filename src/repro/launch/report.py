"""Assemble EXPERIMENTS.md from dry-run records + the §Perf iteration log.

    PYTHONPATH=src python -m repro.launch.report
"""

from __future__ import annotations

import glob
import json
import os

from repro.launch.roofline import fmt_s, load_rows, to_markdown

HEADER = """# EXPERIMENTS — MAX (CIKM'19) as a multi-pod JAX framework

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI,
16 GiB HBM per chip. Meshes: single pod (16, 16) = ("data", "model");
multi-pod (2, 16, 16) = ("pod", "data", "model"). All numbers are derived
from AOT `.lower().compile()` artifacts on 512 forced host devices — no TPU
in the container (see §Methodology).

## §Validation vs the paper's own claims

The paper is a demo/system paper with no quantitative tables; its claims are
architectural, and each is validated by a test or benchmark:

| Paper claim | Where validated |
|---|---|
| Wrap any model behind `_pre_process/_predict/_post_process` | tests/test_core_wrapper.py (hook chain); examples/add_model.py wraps a non-LLM |
| Standardized JSON envelope `{"status": "ok", "predictions": [...]}` (Fig. 3) | test_core_wrapper.py::test_sentiment_envelope_matches_paper_fig3 — byte-for-byte shape |
| Swap the underlying model with zero client change | test_api_http.py::test_model_swap_zero_client_change — one client fn, 4 architecture families |
| RESTful endpoints + auto Swagger per model | test_api_http.py (metadata/labels/predict/swagger round-trips over real HTTP) |
| Registry of wrapped assets (30+ in the paper) | 12+ assets incl. all 10 assigned archs; test_core_wrapper.py |
| Container isolation per model | core/deployment.py (program/arena/mesh-slice isolation); fault isolation tested via bad-input requests |
| MAX-Skeleton add-a-model flow | core/skeleton.py + examples/add_model.py + test_skeleton_flow |
| Wrapper adds negligible overhead | benchmarks: fig3_wrapper_* (envelope vs raw jit call) |

## §Dry-run

Every (architecture x input-shape x mesh) combination lowers AND compiles
for the production meshes: **80 combinations = 10 archs x 4 shapes x
{single pod 256 chips, 2 pods 512 chips}**, of which 12 are *recorded
skips* (`long_500k` on the 6 pure-full-attention/enc-dec archs — see
DESIGN.md §Arch-applicability) and the rest compile successfully.

Two full sweeps are recorded:

- `experiments/dryrun_baseline/` — paper-faithful baseline (all §Perf
  optimizations off),
- `experiments/dryrun_opt/` — optimized configuration (chunked WKV,
  carry-cache decode, buffer donation, weight-gather FSDP, fp8 KV cache
  for decode).

Per-record JSON: memory analysis (argument/output/temp/alias bytes per
device), XLA cost analysis, trip-count-scaled HLO cost model
(FLOPs / HBM bytes / per-collective wire bytes), sharding fallbacks, and
microbatch/moment-dtype choices.

### Methodology notes

1. **Loop scaling.** `compiled.cost_analysis()` visits `while` bodies once
   (verified: a 10-iteration scan reports 1x flops). Our analyzer
   (launch/hlo_analysis.py) parses the post-SPMD HLO, reads XLA's
   `known_trip_count` backend config, and scales body costs through the
   call graph. Validated on closed-form programs (tests/test_hlo_analysis.py)
   and against MODEL_FLOPS (llama3-405b train: HLO/analytic = 1.29, i.e.
   the remat recompute overhead — 8/6 ~ 1.33 expected).
2. **HBM traffic.** Post-fusion operand+output bytes, with in-place
   semantics for dynamic-slice / dynamic-update-slice (incl. fusion
   introspection: fusion parameters consumed only via dynamic-slice bill
   the slice). Without this the sequential-scan archs over-count by >100x.
3. **Wire bytes.** Ring formulas per collective from per-device HLO shapes
   and `replica_groups`: all-reduce 2B(n-1)/n, all-gather/reduce-scatter/
   all-to-all B(n-1)/n, collective-permute B.
4. **CPU-pipeline caveats.** XLA:CPU's SPMD pipeline (a) never forms
   reduce-scatters — gradient partial sums lower as full all-reduces, and
   (b) does not sink dtype converts below collectives. Both inflate the
   collective term of train shapes vs a real TPU lowering; §Perf H2
   quantifies the gap analytically.

## §Roofline
"""

PERF = r"""
## §Perf — hypothesis -> change -> measure -> validate

Three pairs hillclimbed (selection per assignment): **rwkv6-7b train_4k**
(worst roofline fraction), **llama3-405b train_4k** (most collective-bound),
**llama3-405b decode_32k** (most representative of the paper's serving
technique). All numbers: single-pod mesh, per-chip terms in seconds.

### H1 — rwkv6-7b train_4k (memory term 105,623 s at baseline*)

*Baseline measured with the pre-fix traffic model; re-measured baseline
under the final analyzer: see the baseline table. The catastrophic term was
real either way: the per-token WKV scan round-trips the [B,H,64,64] f32
state through HBM 4096 times per layer.

| iter | hypothesis | change | before -> after (mem term) | verdict |
|---|---|---|---|---|
| 1 | per-token state HBM traffic dominates; carrying state per *chunk* cuts it by the chunk length | chunked WKV, relative-decay D-tensor form (chunk 32) | 105,623 s -> 2,134 s | **confirmed** (49x) but still memory-bound: the 5-D decay tensor materializes |
| 2 | factorizing the intra-chunk interaction into two MXU matmuls removes the 5-D tensor; a decay clamp (d <= 1.5) bounds `exp(-logW)` so the factorization is f32-safe | `_wkv_chunked` factorized (chunk 16) + `DECAY_CLAMP` | 2,134 s -> 836 s | **confirmed** direction; remaining traffic traced to the *analyzer* billing full carried buffers per iteration |
| 3 | the traffic model, not the program, bills in-place loop slices as full-buffer traffic | analyzer: in-place semantics for DS/DUS + fusion introspection | 836 s -> 27.7 s | **confirmed** — and exposed the true profile: rwkv6 train is now *collective*-bound (60.5 s, FSDP all-reduces -> fixed by H2's weight-gather, shared fix) |

Chunked == sequential to 2.6e-5 (tests/test_recurrent.py + direct check);
the Pallas WKV kernel achieves the same state-locality on TPU by carrying
S in VMEM scratch (kernels/rwkv6.py).

### H2 — llama3-405b train_4k (collective term 2,988 s at baseline)

Napkin: 6ND/chip = 9.97e15 flops -> compute 50 s (65 s with remat).
Megatron-SP collective floor ~ 4x activations/layer ~ 3.2e12 B -> ~65 s.
Baseline wire = 1.49e14 B = 2,988 s — 45x over the floor.

| iter | hypothesis | change | before -> after (wire) | verdict |
|---|---|---|---|---|
| 1 | GSPMD partial-sums fsdp-sharded contractions and all-reduces activations; gathering weights per layer (ZeRO-3 schedule) is 40x cheaper | `maybe_gather_params` at layer-body top (fwd + remat'd bwd) | 2,988 s -> 2,229 s | **partially confirmed** (all-reduce 8.3e13 -> 4.7e13); all-gather unchanged — dominated by f32 *weight* gathers in the backward pass, not activations. **Corollary finding:** blindly gathering MoE *expert* weights destroys the expert-parallel schedule (qwen3-moe train compute 5.2 s -> 49.5 s, useful ratio 0.06) — expert leaves are excluded from the gather (sharding/specs.py) |
| 2 | my forced q/k/v head-sharding annotations add a resharding boundary; dropping them under the gather schedule removes gathers | conditional annotate | 2,229 s -> 2,453 s | **refuted** — propagation chose worse shardings; reverted |
| 3 | the f32 full-weight all-reduce tuple is the *gradient* reduction: unannotated f32 grad accumulators got replicated by the solver | `shard_like_params` on accumulators | no change | **refuted on this backend** — inspection shows XLA:CPU satisfies the constraint by slicing *after* a full all-reduce; it never forms reduce-scatters. Kept (correct + required for TPU, where SPMD emits reduce-scatter directly) |
| 4 | bf16 gradient accumulation halves grad-collective bytes | `accum_dtype=bf16` for >=60B params (+ cast-at-source variant) | wire unchanged; live memory 55.7 -> 52.2 GiB | **refuted for wire** (convert not sunk below the all-reduce on CPU pipeline); **confirmed for memory**; kept |

**Finding:** on a TPU lowering (reduce-scatter formation + bf16 backward
weight gathers), the same HLO's collective term is analytically
~(6.4e13/2 [bf16 gathers] + 4.7e13/16 [reduce-scatter]) / 50 GB/s ~ 700 s,
and the grad reduction overlaps the microbatch loop — the structural fixes
land here (weight-gather schedule + sharded accumulators), the remaining
gap is backend, not model. Memory: llama3-405b train does NOT fit a single
v5e-256 pod (52 GiB/chip live; weights+moments alone are 9.6 GiB before
activations) — it fits the 2-pod mesh at ~26 GiB only with further
microbatching; the honest conclusion is 405B-train wants >= 4 pods or a
sharded-optimizer regime beyond this repo's scope.

### H3 — llama3-405b decode_32k (the paper's serving case; did not fit: 42.6 GiB/chip)

| iter | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| 1 | no donation: cache counted in args AND outputs | `donate_argnums` on cache (and train state) | live 42.6 -> 37.0 GiB | **confirmed** (args halved) — exposed 25.8 GiB temps: the layer scan double-buffers the cache as xs->ys streams |
| 2 | carrying the cache through the scan (in-place DUS) removes the second stack | carry-cache decode | temps 25.8 -> 7.7 GiB; live -> 19.0 GiB; mem term 4.35 s | **confirmed** |
| 3a | per-batch scatter writes defeat in-place updates; lockstep (scalar-index) writes lower cleaner | uniform-decode DUS (two-level) | mem 4.35 -> 8.15 s | **refuted** — GSPMD reshards traced-index writes on the model-sharded cache S dim |
| 3b | a single-level DUS straight into the [L,B,S,KV,hd] carry avoids the slice write-back | single DUS | mem -> 12.0 s | **refuted** — worse; per-batch scatter was already optimal under sequence sharding. Both variants kept behind `uniform_decode` flag as negative results |
| 4 | fp8 KV cache halves cache bytes end-to-end (vLLM-style; attention upcasts on read) | `cache_dtype=float8_e4m3fn` | mem 4.35 -> 2.34 s; live 19.0 -> **15.1 GiB — FITS** | **confirmed**; drift vs bf16 cache 4.9e-2 on random-weight logits (tests) |

Stopping rule hit for all three pairs (3 consecutive <5% or refuted
iterations on the dominant term).

Prefill rows show 1.00x baseline->optimized by design: per the assignment,
the non-hillclimbed pairs are reported baseline-only. Their dominant memory
terms come from the pure-jnp query-chunked attention materializing f32
score blocks — precisely the traffic the Pallas flash kernel
(kernels/flash_attention.py) keeps in VMEM on the real TPU target; the
kernel is validated bit-for-bit in interpret mode but cannot lower in the
CPU dry-run, so its effect is not visible in these tables.

### Beyond-paper summary

The paper contributes no performance mechanism — its wrapper/registry/REST
layer is reproduced faithfully and validated above. Everything in §Perf is
beyond-paper engineering: chunked WKV, ZeRO-3-style weight gathering,
sharded grad accumulators, donation + carry-cache decode, fp8 KV cache,
sequence-parallel residual activations, and context-parallel (sequence-
shardable) decode attention — plus 5 Pallas TPU kernels for the hot spots.
"""


def build(out_path="EXPERIMENTS.md"):
    parts = [HEADER]

    for profile, d in (("baseline", "experiments/dryrun_baseline"),
                       ("optimized", "experiments/dryrun_opt")):
        if not os.path.isdir(d) or not glob.glob(os.path.join(d, "*.json")):
            continue
        rows = load_rows(d, "single")
        n_ok = sum(r.status == "ok" for r in rows)
        n_skip = sum(r.status == "skipped" for r in rows)
        parts.append(f"\n### Single-pod roofline — {profile} "
                     f"({n_ok} ok, {n_skip} recorded skips)\n\n")
        parts.append(to_markdown(rows))
        rows_m = load_rows(d, "multi")
        ok_m = sum(r.status == "ok" for r in rows_m)
        sk_m = sum(r.status == "skipped" for r in rows_m)
        er_m = [r for r in rows_m if r.status == "error"]
        parts.append(f"\nMulti-pod (512-chip) {profile}: {ok_m} compile ok, "
                     f"{sk_m} recorded skips, {len(er_m)} errors"
                     + (": " + "; ".join(f"{r.arch}/{r.shape}" for r in er_m)
                        if er_m else "") + ".\n")

    # multi-pod scaling: pod-axis overhead on the optimized sweep
    odir = "experiments/dryrun_opt"
    if os.path.isdir(odir):
        single = {(r.arch, r.shape): r for r in load_rows(odir, "single")
                  if r.status == "ok"}
        multi = {(r.arch, r.shape): r for r in load_rows(odir, "multi")
                 if r.status == "ok"}
        parts.append(
            "\n### Multi-pod scaling (optimized; 256 -> 512 chips)\n\n"
            "Per-chip terms should halve under perfect weak scaling of the "
            "data axis; the collective delta is the pod-axis (DCN-crossing "
            "gradient all-reduce) overhead.\n\n"
            "| arch | shape | compute 1p->2p | memory 1p->2p | "
            "collective 1p->2p | HBM/chip 1p->2p |\n|---|---|---|---|---|---|\n")
        for key in sorted(single):
            if key not in multi:
                continue
            s, m = single[key], multi[key]
            if key[1] not in ("train_4k", "decode_32k"):
                continue
            parts.append(
                f"| {key[0]} | {key[1]} | {fmt_s(s.compute_s)}->"
                f"{fmt_s(m.compute_s)} | {fmt_s(s.memory_s)}->"
                f"{fmt_s(m.memory_s)} | {fmt_s(s.collective_s)}->"
                f"{fmt_s(m.collective_s)} | {s.hbm_gib_per_chip:.1f}->"
                f"{m.hbm_gib_per_chip:.1f}GiB |\n")

        parts.append(
            "\nFindings: dense/SSM/hybrid archs weak-scale cleanly "
            "(compute & memory halve; collectives halve for train since "
            "the data axis doubles). Two regressions are real and "
            "structural: (i) **MoE train/decode degrade cross-pod** "
            "(qwen3-moe train collective 942 -> 1294 s, HBM/chip 38 -> 66 "
            "GiB) — expert-parallel all-to-alls and expert weights do not "
            "shard over the pod axis, so doubling pods duplicates expert "
            "state and adds DCN-crossing dispatch; an expert-x-pod sharding "
            "rule is the obvious next lever. (ii) **llama3-405b train "
            "HBM/chip rises 52 -> 73 GiB**: the microbatch heuristic halves "
            "num_microbatches on 2 pods (data axis 32), doubling per-micro "
            "activation carries — fixed by pinning tokens-per-microbatch "
            "instead of microbatch count.\n")

    # baseline -> optimized improvement summary
    bdir, odir = "experiments/dryrun_baseline", "experiments/dryrun_opt"
    if os.path.isdir(bdir) and os.path.isdir(odir):
        base = {(r.arch, r.shape): r for r in load_rows(bdir, "single")
                if r.status == "ok"}
        opt = {(r.arch, r.shape): r for r in load_rows(odir, "single")
               if r.status == "ok"}
        rows = []
        for key in sorted(base):
            if key not in opt:
                continue
            b, o = base[key], opt[key]
            if b.step_s <= 0:
                continue
            gain = b.step_s / max(o.step_s, 1e-12)
            rows.append((gain, key, b, o))
        rows.sort(reverse=True)
        parts.append(
            "\n### Baseline -> optimized (single pod, dominant-term "
            "step bound)\n\n"
            "| arch | shape | baseline bound | optimized bound | x | "
            "fits b->o |\n|---|---|---|---|---|---|\n")
        for gain, (arch, shape), b, o in rows:
            parts.append(
                f"| {arch} | {shape} | {fmt_s(b.step_s)} | {fmt_s(o.step_s)} "
                f"| {gain:.2f}x | {'y' if b.fits else 'N'}->"
                f"{'y' if o.fits else 'N'} |\n")
        n_fit_b = sum(1 for *_, b, o in rows if b.fits)
        n_fit_o = sum(1 for *_, b, o in rows if o.fits)
        parts.append(f"\nPairs fitting 16 GiB/chip: baseline {n_fit_b}"
                     f"/{len(rows)} -> optimized {n_fit_o}/{len(rows)}.\n")

    parts.append(PERF)

    # perf-iteration raw records
    tagged = sorted(glob.glob("experiments/perf/*.json"))
    if tagged:
        parts.append("\n### §Perf raw iteration records\n\n"
                     "| record | status | mem term | wire term | note |\n"
                     "|---|---|---|---|---|\n")
        for path in tagged:
            rec = json.load(open(path))
            if rec.get("status") != "ok":
                continue
            hc = rec.get("hlo_cost", {})
            parts.append(
                f"| {os.path.basename(path)} | {rec['status']} "
                f"| {fmt_s(hc.get('hbm_bytes', 0) / 819e9)} "
                f"| {fmt_s(hc.get('wire_bytes', 0) / 50e9)} "
                f"| {rec.get('tag', '')} |\n")

    with open(out_path, "w") as f:
        f.write("".join(parts))
    print(f"wrote {out_path}")


if __name__ == "__main__":
    build()
