# NOTE: deliberately does NOT import dryrun (it sets XLA device-count flags
# at import time). Import repro.launch.dryrun explicitly and first.
from repro.launch.mesh import (
    HBM_BW, HBM_PER_CHIP, ICI_BW, PEAK_FLOPS_BF16,
    make_production_mesh, make_test_mesh, num_chips,
)
