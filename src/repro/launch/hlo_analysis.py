"""Static HLO cost analysis with loop trip-count scaling.

XLA's built-in ``compiled.cost_analysis()`` visits each ``while`` body ONCE
(verified empirically), which under-counts every scanned-layer model by a
factor of ``num_layers`` (and microbatch loops, attention chunk loops...).
This module parses the post-SPMD HLO text and:

1. builds the computation call graph (while bodies/conditions, fusions,
   calls, conditionals),
2. extracts loop trip counts (largest integer constant in the loop's
   condition computation — exact for lax.scan-lowered loops),
3. computes, with multipliers,
   - FLOPs  (dot/convolution contributions; elementwise excluded — matmul-
     dominated transformer workloads),
   - HBM bytes (operand + output bytes of top-level ops; fusion-internal
     ops are excluded since their temps never hit HBM),
   - per-collective wire bytes (standard ring formulas, per device):
       all-reduce       2·B·(n-1)/n
       all-gather       B_out·(n-1)/n
       reduce-scatter   B_in·(n-1)/n
       all-to-all       B·(n-1)/n
       collective-permute  B

All shapes in the post-SPMD module are PER-DEVICE shapes, so totals are
per-device quantities — exactly what the roofline terms need.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^)]*?\)?[\w\[\],\s]*?)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# opcodes whose operands/outputs never touch HBM as standalone buffers
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # while/call/conditional traffic is accounted inside their bodies;
    # counting the carried tuple at the call site would bill the full
    # loop state per iteration of the PARENT
    "while", "call", "conditional",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str            # operand list + attributes (raw tail of the line)
    operand_names: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    defs: Dict[str, str] = field(default_factory=dict)   # op name -> type str
    is_entry: bool = False


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and stripped.rstrip().endswith("{"):
                cur = Computation(m.group(1),
                                  is_entry=stripped.startswith("ENTRY"))
                comps[cur.name] = cur
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        operands = re.findall(r"%([\w\.\-]+)", rest.split(")")[0])
        op = Op(name, type_str.strip(), opcode, rest, operands)
        cur.ops.append(op)
        cur.defs[name] = op.type_str
    return comps


def _called_computations(op: Op) -> List[Tuple[str, str]]:
    """Returns [(callee_name, kind)] where kind in {loop, fusion, call}."""
    out = []
    for attr, kind in (("body", "loop"), ("condition", "loop_cond"),
                       ("calls", "fusion"), ("to_apply", "apply")):
        for m in re.finditer(attr + r"=%?([\w\.\-]+)", op.rest):
            out.append((m.group(1), kind))
    for m in re.finditer(r"branch_computations=\{([^}]*)\}", op.rest):
        for nm in re.findall(r"%?([\w\.\-]+)", m.group(1)):
            out.append((nm, "call"))
    if op.opcode == "call":
        for m in re.finditer(r"to_apply=%?([\w\.\-]+)", op.rest):
            pass  # already captured above
    return out


def _trip_count(cond: Computation, body: Computation) -> int:
    """Largest integer constant in the loop condition (lax.scan bound)."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"(\d+)\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: Op, comp: Computation) -> int:
    out_dims = _shape_dims(op.type_str)
    out_elems = math.prod(out_dims) if out_dims else 0
    # contracting dim sizes from lhs operand shape
    lhs_name = op.operand_names[0] if op.operand_names else None
    lhs_type = comp.defs.get(lhs_name, "")
    if not lhs_type:
        m = re.search(r"\(\s*(\w+\[[\d,]*\])", op.rest)
        lhs_type = m.group(1) if m else ""
    lhs_dims = _shape_dims(lhs_type)
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2 * out_elems * max(contract, 1)


def _group_size(op: Op, default: int) -> int:
    # iota format: replica_groups=[G,n]<=[...]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.rest)
    if m:
        return int(m.group(2))
    # explicit: replica_groups={{0,1,2,...},{...}}
    m = re.search(r"replica_groups=\{\{([^}]*)\}", op.rest)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    return default


def _traffic_bytes(op: Op, comp: Computation, comps: Dict[str, "Computation"],
                   ) -> float:
    """HBM traffic estimate for one op, respecting in-place slice semantics.

    ``dynamic-slice`` reads only the slice; ``dynamic-update-slice`` into a
    loop-carried buffer rewrites only the updated region (XLA performs these
    in place). Billing the full carried buffer per iteration over-counted
    RWKV train HBM by ~120x (see EXPERIMENTS.md §Roofline methodology).
    Fusions whose root is a (dynamic-)update-slice are treated likewise:
    the largest operand is assumed aliased in place.
    """
    oc = op.opcode
    out_b = _shape_bytes(op.type_str)
    if oc == "dynamic-slice":
        return 2.0 * out_b                      # read slice + write copy
    if oc == "dynamic-update-slice":
        ops_b = [_shape_bytes(comp.defs.get(nm, "")) for nm in
                 op.operand_names]
        upd = ops_b[1] if len(ops_b) > 1 else 0
        return 2.0 * upd
    if oc == "fusion":
        return _fusion_traffic(op, comp, comps)
    return _operand_bytes(op, comp) + out_b


def _fusion_traffic(op: Op, comp: Computation,
                    comps: Dict[str, "Computation"]) -> float:
    """Introspect the fused computation: parameters consumed only through
    ``dynamic-slice`` bill the slice (xs streams in while loops); a
    ``dynamic-update-slice`` root writes only the update region and aliases
    its big operand in place."""
    out_b = _shape_bytes(op.type_str)
    m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
    callee = comps.get(m.group(1)) if m else None
    if callee is None:
        return _operand_bytes(op, comp) + out_b
    total = 0.0
    for p in callee.ops:
        if p.opcode != "parameter":
            continue
        consumers = [o for o in callee.ops if p.name in o.operand_names]
        if consumers and all(o.opcode == "dynamic-slice" for o in consumers):
            total += sum(_shape_bytes(o.type_str) for o in consumers)
        else:
            total += _shape_bytes(p.type_str)
    root = callee.ops[-1] if callee.ops else None
    if root is not None and root.opcode == "dynamic-update-slice":
        big = _shape_bytes(callee.defs.get(root.operand_names[0], "")) \
            if root.operand_names else 0
        upd = _shape_bytes(callee.defs.get(root.operand_names[1], "")) \
            if len(root.operand_names) > 1 else out_b
        total = max(total - big, 0.0) + upd
        out_b = upd
    return total + out_b


def _operand_bytes(op: Op, comp: Computation) -> int:
    total = 0
    for nm in op.operand_names:
        t = comp.defs.get(nm)
        if t:
            total += _shape_bytes(t)
    if total == 0:
        # fall back: inline types in the operand list
        total = _shape_bytes(op.rest.split(")")[0])
    return total


def _wire_bytes(op: Op, comp: Computation, n_devices: int) -> float:
    n = max(_group_size(op, n_devices), 1)
    out_b = _shape_bytes(op.type_str)
    in_b = _operand_bytes(op, comp)
    frac = (n - 1) / n if n > 1 else 0.0
    if op.opcode.startswith("all-reduce"):
        return 2.0 * out_b * frac
    if op.opcode.startswith("all-gather"):
        return out_b * frac
    if op.opcode.startswith("reduce-scatter"):
        return in_b * frac
    if op.opcode.startswith("all-to-all"):
        return out_b * frac
    if op.opcode.startswith("collective-permute"):
        return float(out_b)
    return 0.0


@dataclass
class HLOCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    loop_trips: Dict[str, int] = field(default_factory=dict)

    def to_json(self):
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "collective_counts": self.collective_counts,
            "collective_bytes": self.collective_bytes,
            "loop_trips": self.loop_trips,
        }


def analyze(text: str, n_devices: int) -> HLOCost:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # multipliers: full (HBM+flops+wire) and flops-only (fusion internals)
    mult_full: Dict[str, float] = defaultdict(float)
    mult_flops: Dict[str, float] = defaultdict(float)
    mult_full[entry.name] = 1.0

    cost = HLOCost()

    # BFS through the call graph computing multipliers
    order = [entry.name]
    seen = {entry.name}
    idx = 0
    while idx < len(order):
        cname = order[idx]
        idx += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        base_full = mult_full[cname]
        base_flops = mult_flops[cname] + base_full
        for op in comp.ops:
            for callee, kind in _called_computations(op):
                if callee not in comps:
                    continue
                if kind == "loop":
                    # authoritative: XLA's known_trip_count backend config
                    trips = None
                    m = re.search(
                        r'known_trip_count[\\"\s:{]+n[\\"\s:]+(\d+)', op.rest)
                    if m:
                        trips = int(m.group(1))
                    if trips is None:
                        cond_name = None
                        m = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                        if m:
                            cond_name = m.group(1)
                        trips = 1
                        if cond_name and cond_name in comps:
                            trips = _trip_count(comps[cond_name], comps[callee])
                    cost.loop_trips[callee] = trips
                    mult_full[callee] += base_flops * trips
                elif kind == "loop_cond":
                    pass  # condition bodies are negligible
                elif kind == "fusion":
                    mult_flops[callee] += base_flops
                elif kind in ("call", "apply"):
                    mult_full[callee] += base_flops
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    # second pass: accumulate costs
    for cname in order:
        comp = comps.get(cname)
        if comp is None:
            continue
        mf = mult_full[cname]
        mfl = mult_full[cname] + mult_flops[cname]
        if mf == 0 and mfl == 0:
            continue
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                cost.flops += _dot_flops(op, comp) * mfl
            if mf > 0 and op.opcode not in _NO_TRAFFIC:
                cost.hbm_bytes += _traffic_bytes(op, comp, comps) * mf
            if any(op.opcode.startswith(c) for c in COLLECTIVES):
                wb = _wire_bytes(op, comp, n_devices) * max(mf, mfl)
                cost.wire_bytes += wb
                key = op.opcode.split(".")[0]
                cost.collective_counts[key] = (
                    cost.collective_counts.get(key, 0) + int(max(mf, mfl)))
                cost.collective_bytes[key] = (
                    cost.collective_bytes.get(key, 0.0) + wb)
    return cost
